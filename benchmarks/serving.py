"""Serving benchmark: query latency/throughput under a live write
trickle, snapshot-swap staleness, and the batch-vs-scalar query speedup
(``serve.service`` / ``serve.ranking``; DESIGN.md §8).

Phases against one ``TriclusterService`` over a movielens-like stream:

1. **load** — a writer thread trickles upserts/deletes (the background
   thread re-mines and swaps snapshots) while the main thread issues
   ranked entity queries as fast as they complete, recording per-query
   latency (p50/p99 wall, plus the handler-CPU / off-CPU-wait split so
   tail latency is attributable to queue wait vs handler work),
   throughput, and the served snapshot's *staleness* (age of the
   published snapshot at query time).  Every sampled query also proves
   the swap is atomic: the observed snapshot's index holds exactly its
   own result's kept clusters and versions never go backwards — a torn
   swap would fail either check.
2. **batch-vs-scalar** — quiesced, top-k for E ∈ {16, 64, 256} entities
   via the scalar dict-probe loop vs the stacked-window batched pass,
   interleaved best-of-``repeat``.
3. **delta probe** (``serving_scale.delta``) — full
   ``ClusterIndex.from_result`` rebuild vs
   ``ClusterIndex.delta_from_result`` splice after a small (few-%%-of-
   clusters-dirty) update, best-of-``repeat``, with the delta result
   asserted **bit-identical** to the full rebuild.
4. **replica scale-out** (``serving_scale.replica_scaleout``) — a
   sharded plane (2 writer processes mirroring snapshots to shared
   memory, 2 zero-copy replica readers each, fronted by a
   ``serve.router``) vs the single-process full-rebuild baseline, same
   write trickle and client count on both sides; records the aggregate
   replica qps ratio, per-endpoint consistency (replica answers equal
   the writer's at a pinned version) and cross-shard read-your-writes
   through the router token.

The resulting ``serving`` + ``serving_scale`` sections ride in
BENCH_mining.json and are schema-gated by ``benchmarks/validate.py``
(CI bench-smoke).
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from repro.data import synthetic
from repro.serve.service import TriclusterService

from .common import print_table, save_json

BATCH_SIZES = (16, 64, 256)
TOP_K = 8
#: replica scale-out topology (shards x replicas) and load clients
SCALEOUT_SHARDS = 2
SCALEOUT_REPLICAS = 2
SCALEOUT_CLIENTS = 4


def _load_phase(svc: TriclusterService, ctx, duration_s: float,
                seed: int = 1) -> dict:
    """Queries against a live write trickle; returns latency/staleness/
    consistency measurements."""
    rng = np.random.default_rng(seed)
    n = ctx.tuples.shape[0]
    stop = threading.Event()
    writer_ops = [0]

    def writer():
        wrng = np.random.default_rng(seed + 1)
        while not stop.is_set():
            sel = wrng.integers(0, n, 4)
            svc.upsert(ctx.tuples[sel],
                       None if ctx.values is None else ctx.values[sel])
            if writer_ops[0] % 8 == 7:
                svc.delete(ctx.tuples[wrng.integers(0, n, 1)])
            writer_ops[0] += 1
            time.sleep(0.002)

    lat, cpu, stale = [], [], []
    consistent = True
    last_version = 0
    t = threading.Thread(target=writer, daemon=True)
    t.start()
    t_end = time.monotonic() + duration_s
    i = 0
    while time.monotonic() < t_end:
        e = int(rng.integers(0, svc.sizes[0]))
        t0 = time.perf_counter()
        c0 = time.thread_time()
        res = svc.query(entity=e, mode=0, k=TOP_K)
        cpu.append((time.thread_time() - c0) * 1e3)
        lat.append((time.perf_counter() - t0) * 1e3)
        snap = svc.snapshot()
        stale.append((time.monotonic() - snap.published_at) * 1e3)
        if res.version < last_version:        # versions must be monotone
            consistent = False
        last_version = max(last_version, res.version)
        if i % 32 == 0:
            # complete-snapshot invariant: the index a query sees holds
            # exactly the kept clusters of the result it was built from
            if len(snap.index) != int(np.asarray(snap.result.keep).sum()):
                consistent = False
        i += 1
    stop.set()
    t.join(timeout=10)
    lat = np.asarray(lat)
    # tail attribution: handler CPU (the query's own work) vs off-CPU
    # wait (descheduled behind the miner/writer threads — the
    # in-process analogue of HTTP queue wait; cf. ``server_ms`` in
    # serve.protocol for the over-the-wire split)
    wait = np.maximum(np.asarray(lat) - np.asarray(cpu), 0.0)
    return {"queries": int(lat.size), "duration_s": float(duration_s),
            "qps": float(lat.size / duration_s),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "p99_handler_ms": float(np.percentile(cpu, 99)),
            "p99_wait_ms": float(np.percentile(wait, 99)),
            "writer_ops": int(writer_ops[0]),
            "staleness_ms_mean": float(np.mean(stale)),
            "staleness_ms_max": float(np.max(stale)),
            "consistent": bool(consistent)}


def _batch_phase(svc: TriclusterService, repeat: int, seed: int = 2
                 ) -> list:
    """Interleaved best-of-``repeat`` scalar-loop vs batched top-k."""
    rng = np.random.default_rng(seed)
    out = []
    for n_ent in BATCH_SIZES:
        ents = rng.integers(0, svc.sizes[0], n_ent).tolist()
        best = {"scalar": float("inf"), "batch": float("inf")}
        for _ in range(max(1, repeat)):
            t0 = time.perf_counter()
            scalar = [svc.query(entity=e, mode=0, k=TOP_K).hits
                      for e in ents]
            best["scalar"] = min(best["scalar"],
                                 (time.perf_counter() - t0) * 1e3)
            t0 = time.perf_counter()
            batched = svc.query_batch(ents, mode=0, k=TOP_K).hits
            best["batch"] = min(best["batch"],
                                (time.perf_counter() - t0) * 1e3)
        # the batched path must answer exactly what the scalar loop does
        assert [[v.signature for v, _ in per] for per in scalar] \
            == [[v.signature for v, _ in per] for per in batched], \
            f"batch/scalar mismatch at {n_ent} entities"
        out.append({"entities": int(n_ent),
                    "scalar_ms": best["scalar"], "batch_ms": best["batch"],
                    "speedup": best["scalar"] / max(best["batch"], 1e-9)})
    return out


def _index_identical(a, b) -> bool:
    """Bit-identity of two ClusterIndex builds: every stacked array and
    every per-cluster stat must match exactly."""
    if not (np.array_equal(a.packed_sigs, b.packed_sigs)
            and np.array_equal(a.any_pairs, b.any_pairs)):
        return False
    for pa, pb in zip(a.mode_pairs, b.mode_pairs):
        if not np.array_equal(pa, pb):
            return False
    for ea, eb in zip(a.comp_ents, b.comp_ents):
        if not np.array_equal(ea, eb):
            return False
    for ba, bb in zip(a.comp_bounds, b.comp_bounds):
        if not np.array_equal(ba, bb):
            return False
    return all(va.signature == vb.signature and va.density == vb.density
               and va.gen_count == vb.gen_count
               and va.volume == vb.volume
               for va, vb in zip(a.clusters, b.clusters))


def _delta_probe(scale: float, repeat: int, seed: int = 3) -> dict:
    """Full ``from_result`` rebuild vs ``delta_from_result`` splice
    after a small update (the swap-critical-path comparison), with the
    delta output asserted bit-identical to the full rebuild."""
    from repro.core import pipeline as P
    from repro.core.streaming import StreamingMiner
    from repro.serve.clusters import ClusterIndex

    n = max(2_000, int(1_000_000 * scale))
    ctx = synthetic.movielens_like(n_tuples=n, seed=seed)
    m = StreamingMiner(ctx.sizes, seed=seed)
    m.upsert(ctx.tuples)
    res1 = m.snapshot()
    idx1 = ClusterIndex.from_result(res1)
    sigs1 = P.kept_sig_words(res1)
    # a small localized update: a handful of novel tuples (plus one
    # delete), so only a few %% of cluster signatures go dirty
    rng = np.random.default_rng(seed + 1)
    k = max(4, n // 4000)
    m.upsert(rng.integers(0, ctx.sizes, size=(k, len(ctx.sizes)))
             .astype(np.int64))
    m.delete(ctx.tuples[rng.integers(0, len(ctx.tuples), 1)])
    res2 = m.snapshot()
    dirty = P.dirty_sig_count(sigs1, P.kept_sig_words(res2))

    best = {"full": float("inf"), "delta": float("inf")}
    delta_idx = None
    for _ in range(max(2, repeat)):
        t0 = time.perf_counter()
        full_idx = ClusterIndex.from_result(res2)
        best["full"] = min(best["full"],
                           (time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        delta_idx = ClusterIndex.delta_from_result(idx1, res2)
        best["delta"] = min(best["delta"],
                            (time.perf_counter() - t0) * 1e3)
    identical = _index_identical(full_idx, delta_idx)
    assert identical, "delta_from_result diverged from from_result"
    return {"n_tuples": int(n), "clusters": int(len(full_idx)),
            "dirty_clusters": int(dirty),
            "dirty_fraction": float(dirty / max(len(full_idx), 1)),
            "full_ms": best["full"], "delta_ms": best["delta"],
            "speedup": best["full"] / max(best["delta"], 1e-9),
            "identical": bool(identical)}


def _wait_port(path: str, timeout: float = 180.0) -> int:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            with open(path) as f:
                return int(f.read().strip())
        except (OSError, ValueError):
            time.sleep(0.1)
    raise TimeoutError(f"no port in {path}")


def _http_load(endpoints, n_entities: int, duration_s: float,
               n_clients: int, seed: int) -> dict:
    """``n_clients`` threads of persistent-connection entity queries,
    client ``i`` pinned to endpoint ``i % len(endpoints)``; returns
    aggregate qps + per-endpoint version monotonicity."""
    from repro.serve.router import PooledClient

    stop = threading.Event()
    counts = [0] * n_clients
    monotone = [True] * n_clients

    def client(ci: int):
        cl = PooledClient(endpoints[ci % len(endpoints)])
        rng = np.random.default_rng(seed + ci)
        last_v = 0
        while not stop.is_set():
            e = int(rng.integers(0, n_entities))
            out = cl.call("/query", {"entity": e, "mode": 0,
                                     "k": TOP_K})
            if out["version"] < last_v:
                monotone[ci] = False
            last_v = max(last_v, out["version"])
            counts[ci] += 1

    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(n_clients)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    elapsed = time.monotonic() - t0
    return {"queries": int(sum(counts)),
            "qps": float(sum(counts) / elapsed),
            "monotone": all(monotone)}


def _replica_scaleout(scale: float, seed: int = 5) -> dict:
    """Sharded zero-copy plane (writers + shm replicas + router) vs the
    single-process full-rebuild baseline under the same write trickle
    and client count."""
    import multiprocessing as mp

    from repro.launch.cluster_serve import _child_replica, _child_writer
    from repro.serve.router import PooledClient, RouterService, Shard

    n = max(2_000, int(1_000_000 * scale))
    duration = float(min(10.0, max(2.0, 80 * scale)))
    mp_ctx = mp.get_context("spawn")
    tmp = tempfile.mkdtemp(prefix="bench-scaleout-")
    base = {"dataset": "movielens", "n_tuples": n, "seed": seed,
            "backend": "streaming", "theta": 0.0, "delta": None,
            "rho_min": 0.0, "minsup": 0, "refresh_interval": 0.05,
            "dirty_threshold": 16, "policy": (1.0, 0.0, 0.0),
            "preload_chunks": 4, "host": "127.0.0.1", "verbose": False,
            "timeout": 180.0}
    sizes0 = synthetic.movielens_like(n_tuples=4, seed=seed).sizes[0]

    def trickle(write_fn, stop):
        wrng = np.random.default_rng(seed + 99)
        ops = [0]

        def loop():
            while not stop.is_set():
                rows = wrng.integers(0, (sizes0, 1, 1), size=(4, 3))
                write_fn(rows.astype(np.int64).tolist())
                ops[0] += 1
                time.sleep(0.002)

        t = threading.Thread(target=loop, daemon=True)
        t.start()
        return t, ops

    procs, out = [], {"shards": SCALEOUT_SHARDS,
                      "replicas": SCALEOUT_REPLICAS,
                      "clients": SCALEOUT_CLIENTS,
                      "n_tuples": int(n), "duration_s": duration}
    try:
        # ---- baseline: one process, full index rebuild every swap ----
        cfg = dict(base, shard=0, n_shards=1, shm_prefix="",
                   delta_index=False,
                   port_file=os.path.join(tmp, "base.port"))
        p = mp_ctx.Process(target=_child_writer, args=(cfg,),
                           daemon=True)
        p.start()
        procs.append(p)
        bport = _wait_port(cfg["port_file"])
        bcl = PooledClient(f"http://127.0.0.1:{bport}")
        while bcl.call("/health")["version"] < 1:
            time.sleep(0.2)
        stop = threading.Event()
        wt, wops = trickle(lambda r: bcl.call("/upsert", {"rows": r}),
                           stop)
        base_load = _http_load([bcl.base_url], sizes0, duration,
                               SCALEOUT_CLIENTS, seed)
        stop.set()
        wt.join(timeout=10)
        base_load["write_ops"] = int(wops[0])
        bcl.call("/shutdown", {})
        out["baseline"] = base_load

        # ---- sharded plane: writers + shm replicas + router ----------
        shard_specs = []
        for s in range(SCALEOUT_SHARDS):
            prefix = f"bs{os.getpid()}s{s}"
            wcfg = dict(base, shard=s, n_shards=SCALEOUT_SHARDS,
                        shm_prefix=prefix, delta_index=True,
                        port_file=os.path.join(tmp, f"w{s}.port"))
            p = mp_ctx.Process(target=_child_writer, args=(wcfg,),
                               daemon=True)
            p.start()
            procs.append(p)
            rfiles = []
            for r in range(SCALEOUT_REPLICAS):
                rcfg = dict(base, shard=s, replica=r,
                            shm_prefix=prefix,
                            port_file=os.path.join(tmp,
                                                   f"r{s}.{r}.port"))
                p = mp_ctx.Process(target=_child_replica, args=(rcfg,),
                                   daemon=True)
                p.start()
                procs.append(p)
                rfiles.append(rcfg["port_file"])
            shard_specs.append((wcfg["port_file"], rfiles))
        shards, replica_urls = [], []
        for wf, rfiles in shard_specs:
            wp = _wait_port(wf)
            rps = [_wait_port(rf) for rf in rfiles]
            urls = [f"http://127.0.0.1:{rp}" for rp in rps]
            replica_urls.extend(urls)
            # generous HTTP timeouts: /refresh and pinned-version reads
            # block on a full re-mine cycle, which at benchmark scale
            # runs tens of seconds on one busy core
            shards.append(Shard(f"http://127.0.0.1:{wp}", urls,
                                timeout=180.0))
        router = RouterService(shards, timeout=180.0)
        router.health()                       # plane fully attached
        stop = threading.Event()
        wt, wops = trickle(router.upsert, stop)
        plane_load = _http_load(replica_urls, sizes0, duration,
                                SCALEOUT_CLIENTS, seed)
        stop.set()
        wt.join(timeout=10)
        plane_load["write_ops"] = int(wops[0])
        out["plane"] = plane_load

        # consistency: at a pinned per-shard version every replica must
        # answer exactly what its writer answers
        ref = router.refresh()
        tok = ref["shard_versions"]
        consistent = plane_load["monotone"] and base_load["monotone"]
        probe = {"entity": 0, "mode": 0, "k": TOP_K}
        for s, sh in enumerate(shards):
            want = sh.writer.call("/query",
                                  dict(probe, at_least_version=tok[s],
                                       timeout=60))
            for rep in sh.replicas:
                got = rep.call("/query",
                               dict(probe, at_least_version=tok[s],
                                    timeout=60))
                if got["hits"] != want["hits"] \
                        or got["version"] < tok[s]:
                    consistent = False
        # cross-shard read-your-writes through the router token
        routed = router.query(entity=0, mode=0, k=TOP_K,
                              at_least_version=tok, timeout=60)
        ryw = all(v >= t for v, t in zip(routed["shard_versions"], tok))
        out.update(consistent=bool(consistent),
                   read_your_writes=bool(ryw),
                   qps_ratio=float(plane_load["qps"]
                                   / max(base_load["qps"], 1e-9)))
        router.shutdown_backends()
        router.close()
    finally:
        deadline = time.monotonic() + 15
        for p in procs:
            p.join(timeout=max(0.1, deadline - time.monotonic()))
        for p in procs:
            if p.is_alive():
                p.terminate()
    return out


def _obs_overhead(scale: float, repeat: int, seed: int = 7) -> dict:
    """Instrumentation-overhead measurement (DESIGN.md §11 overhead
    budget): the same data served over HTTP twice — once with the
    observability plane off, once with ``--metrics``-equivalent wiring
    (request spans + registry + slow-query log, and the service-side
    swap-path timers) — with client rounds interleaved so drift hits
    both sides equally.  Reports query p50 on/off, snapshot-swap
    latency on/off (best-of: the like-for-like floor), the overhead
    percentages validate.py gates at full scale, and the p99 *derived
    from the registry histogram* — the column render_trend.py tracks
    against the exact client-side p99."""
    from repro.obs import Obs
    from repro.serve.protocol import make_server
    from repro.serve.router import PooledClient

    n = max(2_000, int(1_000_000 * scale))
    ctx = synthetic.movielens_like(n_tuples=n, seed=seed)

    def build(obs):
        svc = TriclusterService(ctx.sizes, refresh_interval=3600.0,
                                dirty_threshold=1 << 30, seed=seed,
                                obs=obs)
        svc.add(ctx.tuples)
        svc.refresh()
        return svc

    # default slow-query threshold: the overhead budget is for the
    # production configuration, not the log-everything debug setting
    obs_on = Obs.create(service="bench")
    svc_off, svc_on = build(None), build(obs_on)
    servers, clients = [], {}
    lat = {"off": [], "on": []}
    swap = {"off": float("inf"), "on": float("inf")}
    try:
        for key, svc, obs in (("off", svc_off, None),
                              ("on", svc_on, obs_on)):
            srv = make_server(svc, port=0, obs=obs)
            threading.Thread(target=srv.serve_forever,
                             daemon=True).start()
            servers.append(srv)
            clients[key] = PooledClient(f"http://127.0.0.1:{srv.port}")

        rng = np.random.default_rng(seed)

        def q():
            return {"entity": int(rng.integers(0, ctx.sizes[0])),
                    "mode": 0, "k": TOP_K}

        for cl in clients.values():               # warm both paths
            for _ in range(20):
                cl.call("/query", q())
        per_round, target = 50, max(400, int(4_000 * scale))
        while len(lat["off"]) < target:
            for key, cl in clients.items():
                for _ in range(per_round):
                    doc = q()
                    t0 = time.perf_counter()
                    cl.call("/query", doc)
                    lat[key].append((time.perf_counter() - t0) * 1e3)

        wrng = np.random.default_rng(seed + 1)
        for _ in range(max(2, repeat)):
            rows = wrng.integers(0, ctx.sizes, size=(8, 3)) \
                       .astype(np.int64)
            for key, svc in (("off", svc_off), ("on", svc_on)):
                svc.upsert(rows)
                t0 = time.perf_counter()
                svc.refresh()
                swap[key] = min(swap[key],
                                (time.perf_counter() - t0) * 1e3)
    finally:
        for srv in servers:
            srv.shutdown()
            srv.server_close()
        svc_off.stop()
        svc_on.stop()

    off, on = np.asarray(lat["off"]), np.asarray(lat["on"])
    p50_off = float(np.percentile(off, 50))
    p50_on = float(np.percentile(on, 50))
    # the registry-derived p99 reads the same series protocol.py wrote
    h = obs_on.metrics.histogram("server_request_ms",
                                 endpoint="/query", role="writer")
    assert h.count >= on.size, "instrumented path missed requests"
    return {"scale": float(scale), "n_tuples": int(n),
            "queries_per_side": int(off.size),
            "query_p50_off_ms": p50_off,
            "query_p50_on_ms": p50_on,
            "query_overhead_pct": 100.0 * (p50_on - p50_off)
            / max(p50_off, 1e-9),
            "query_p99_exact_ms": float(np.percentile(on, 99)),
            "query_p99_hist_ms": float(h.quantile(0.99)),
            "swap_off_ms": float(swap["off"]),
            "swap_on_ms": float(swap["on"]),
            "swap_overhead_pct": 100.0 * (swap["on"] - swap["off"])
            / max(swap["off"], 1e-9),
            "on_samples": int(obs_on.metrics.sample_count()),
            "on_spans": int(len(obs_on.tracer))}


def run(scale: float = 0.12, repeat: int = 3) -> dict:
    n = max(2_000, int(1_000_000 * scale))
    ctx = synthetic.movielens_like(n_tuples=n, seed=0)
    # long enough for several background re-mines + swaps at full scale
    # (a 120k-row snapshot takes seconds); ~1s in the CI smoke run
    duration = float(min(12.0, max(1.0, 100 * scale)))
    svc = TriclusterService(ctx.sizes, refresh_interval=0.05,
                            dirty_threshold=16)
    chunk = -(-n // 8)
    for lo in range(0, n, chunk):
        svc.add(ctx.tuples[lo:lo + chunk])
    raw = {"n_tuples": int(n)}
    with svc:
        svc.query(entity=0, mode=0, k=TOP_K)      # warm the query path
        svc.query_batch([0, 1], mode=0, k=TOP_K)
        raw.update(_load_phase(svc, ctx, duration))
        raw["swaps"] = int(svc.stats()["publishes"])
        raw["mine_ms_mean"] = float(svc.stats()["total_mine_ms"]
                                    / max(svc.stats()["publishes"], 1))
        svc.refresh()                              # quiesce for phase 2
        # at least two interleaved reps even in --repeat 1 smoke runs:
        # the >=2x batch gate in validate.py rides on this comparison
        raw["batch"] = _batch_phase(svc, max(2, repeat))
    at64 = [b["speedup"] for b in raw["batch"] if b["entities"] >= 64]
    raw["batch_speedup_at_64"] = float(max(at64))
    raw["serving_scale"] = {"scale": float(scale),
                            "delta": _delta_probe(scale, repeat),
                            "replica_scaleout": _replica_scaleout(scale)}
    raw["serving_obs"] = _obs_overhead(scale, repeat)
    print_table(
        "serving: query latency under write trickle",
        ["n_tuples", "queries", "qps", "p50_ms", "p99_ms", "p99_wait",
         "swaps", "stale_ms", "consistent"],
        [[f"{n:,}", raw["queries"], f"{raw['qps']:,.0f}",
          f"{raw['p50_ms']:.3f}", f"{raw['p99_ms']:.3f}",
          f"{raw['p99_wait_ms']:.3f}", raw["swaps"],
          f"{raw['staleness_ms_mean']:.1f}", raw["consistent"]]])
    print_table(
        "serving: batch vs scalar top-k",
        ["entities", "scalar_ms", "batch_ms", "speedup"],
        [[b["entities"], f"{b['scalar_ms']:.2f}", f"{b['batch_ms']:.2f}",
          f"{b['speedup']:.2f}x"] for b in raw["batch"]])
    d = raw["serving_scale"]["delta"]
    print_table(
        "serving_scale: delta vs full index rebuild",
        ["clusters", "dirty", "dirty_frac", "full_ms", "delta_ms",
         "speedup", "identical"],
        [[f"{d['clusters']:,}", d["dirty_clusters"],
          f"{d['dirty_fraction']:.4f}", f"{d['full_ms']:.2f}",
          f"{d['delta_ms']:.2f}", f"{d['speedup']:.2f}x",
          d["identical"]]])
    s = raw["serving_scale"]["replica_scaleout"]
    print_table(
        "serving_scale: replica plane vs single-process baseline",
        ["topology", "clients", "base_qps", "plane_qps", "ratio",
         "consistent", "ryw"],
        [[f"{s['shards']}x{s['replicas']}", s["clients"],
          f"{s['baseline']['qps']:,.0f}", f"{s['plane']['qps']:,.0f}",
          f"{s['qps_ratio']:.2f}x", s["consistent"],
          s["read_your_writes"]]])
    o = raw["serving_obs"]
    print_table(
        "serving_obs: instrumentation overhead (metrics on vs off)",
        ["queries", "p50_off", "p50_on", "q_ovh_pct", "swap_off",
         "swap_on", "s_ovh_pct", "p99_exact", "p99_hist"],
        [[o["queries_per_side"], f"{o['query_p50_off_ms']:.3f}",
          f"{o['query_p50_on_ms']:.3f}",
          f"{o['query_overhead_pct']:+.2f}%",
          f"{o['swap_off_ms']:.1f}", f"{o['swap_on_ms']:.1f}",
          f"{o['swap_overhead_pct']:+.2f}%",
          f"{o['query_p99_exact_ms']:.3f}",
          f"{o['query_p99_hist_ms']:.3f}"]])
    save_json("serving.json", raw)
    return raw


if __name__ == "__main__":
    run(scale=0.02, repeat=2)
