"""Serving benchmark: query latency/throughput under a live write
trickle, snapshot-swap staleness, and the batch-vs-scalar query speedup
(``serve.service`` / ``serve.ranking``; DESIGN.md §8).

Three phases against one ``TriclusterService`` over a movielens-like
stream:

1. **load** — a writer thread trickles upserts/deletes (the background
   thread re-mines and swaps snapshots) while the main thread issues
   ranked entity queries as fast as they complete, recording per-query
   latency (p50/p99), throughput, and the served snapshot's *staleness*
   (age of the published snapshot at query time).  Every sampled query
   also proves the swap is atomic: the observed snapshot's index holds
   exactly its own result's kept clusters and versions never go
   backwards — a torn swap would fail either check.
2. **batch-vs-scalar** — quiesced, top-k for E ∈ {16, 64, 256} entities
   via the scalar dict-probe loop vs the stacked-window batched pass,
   interleaved best-of-``repeat``.
3. the resulting ``serving`` section rides in BENCH_mining.json and is
   schema-gated by ``benchmarks/validate.py`` (CI bench-smoke).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.data import synthetic
from repro.serve.service import TriclusterService

from .common import print_table, save_json

BATCH_SIZES = (16, 64, 256)
TOP_K = 8


def _load_phase(svc: TriclusterService, ctx, duration_s: float,
                seed: int = 1) -> dict:
    """Queries against a live write trickle; returns latency/staleness/
    consistency measurements."""
    rng = np.random.default_rng(seed)
    n = ctx.tuples.shape[0]
    stop = threading.Event()
    writer_ops = [0]

    def writer():
        wrng = np.random.default_rng(seed + 1)
        while not stop.is_set():
            sel = wrng.integers(0, n, 4)
            svc.upsert(ctx.tuples[sel],
                       None if ctx.values is None else ctx.values[sel])
            if writer_ops[0] % 8 == 7:
                svc.delete(ctx.tuples[wrng.integers(0, n, 1)])
            writer_ops[0] += 1
            time.sleep(0.002)

    lat, stale = [], []
    consistent = True
    last_version = 0
    t = threading.Thread(target=writer, daemon=True)
    t.start()
    t_end = time.monotonic() + duration_s
    i = 0
    while time.monotonic() < t_end:
        e = int(rng.integers(0, svc.sizes[0]))
        t0 = time.perf_counter()
        res = svc.query(entity=e, mode=0, k=TOP_K)
        lat.append((time.perf_counter() - t0) * 1e3)
        snap = svc.snapshot()
        stale.append((time.monotonic() - snap.published_at) * 1e3)
        if res.version < last_version:        # versions must be monotone
            consistent = False
        last_version = max(last_version, res.version)
        if i % 32 == 0:
            # complete-snapshot invariant: the index a query sees holds
            # exactly the kept clusters of the result it was built from
            if len(snap.index) != int(np.asarray(snap.result.keep).sum()):
                consistent = False
        i += 1
    stop.set()
    t.join(timeout=10)
    lat = np.asarray(lat)
    return {"queries": int(lat.size), "duration_s": float(duration_s),
            "qps": float(lat.size / duration_s),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "writer_ops": int(writer_ops[0]),
            "staleness_ms_mean": float(np.mean(stale)),
            "staleness_ms_max": float(np.max(stale)),
            "consistent": bool(consistent)}


def _batch_phase(svc: TriclusterService, repeat: int, seed: int = 2
                 ) -> list:
    """Interleaved best-of-``repeat`` scalar-loop vs batched top-k."""
    rng = np.random.default_rng(seed)
    out = []
    for n_ent in BATCH_SIZES:
        ents = rng.integers(0, svc.sizes[0], n_ent).tolist()
        best = {"scalar": float("inf"), "batch": float("inf")}
        for _ in range(max(1, repeat)):
            t0 = time.perf_counter()
            scalar = [svc.query(entity=e, mode=0, k=TOP_K).hits
                      for e in ents]
            best["scalar"] = min(best["scalar"],
                                 (time.perf_counter() - t0) * 1e3)
            t0 = time.perf_counter()
            batched = svc.query_batch(ents, mode=0, k=TOP_K).hits
            best["batch"] = min(best["batch"],
                                (time.perf_counter() - t0) * 1e3)
        # the batched path must answer exactly what the scalar loop does
        assert [[v.signature for v, _ in per] for per in scalar] \
            == [[v.signature for v, _ in per] for per in batched], \
            f"batch/scalar mismatch at {n_ent} entities"
        out.append({"entities": int(n_ent),
                    "scalar_ms": best["scalar"], "batch_ms": best["batch"],
                    "speedup": best["scalar"] / max(best["batch"], 1e-9)})
    return out


def run(scale: float = 0.12, repeat: int = 3) -> dict:
    n = max(2_000, int(1_000_000 * scale))
    ctx = synthetic.movielens_like(n_tuples=n, seed=0)
    # long enough for several background re-mines + swaps at full scale
    # (a 120k-row snapshot takes seconds); ~1s in the CI smoke run
    duration = float(min(12.0, max(1.0, 100 * scale)))
    svc = TriclusterService(ctx.sizes, refresh_interval=0.05,
                            dirty_threshold=16)
    chunk = -(-n // 8)
    for lo in range(0, n, chunk):
        svc.add(ctx.tuples[lo:lo + chunk])
    raw = {"n_tuples": int(n)}
    with svc:
        svc.query(entity=0, mode=0, k=TOP_K)      # warm the query path
        svc.query_batch([0, 1], mode=0, k=TOP_K)
        raw.update(_load_phase(svc, ctx, duration))
        raw["swaps"] = int(svc.stats()["publishes"])
        raw["mine_ms_mean"] = float(svc.stats()["total_mine_ms"]
                                    / max(svc.stats()["publishes"], 1))
        svc.refresh()                              # quiesce for phase 2
        # at least two interleaved reps even in --repeat 1 smoke runs:
        # the >=2x batch gate in validate.py rides on this comparison
        raw["batch"] = _batch_phase(svc, max(2, repeat))
    at64 = [b["speedup"] for b in raw["batch"] if b["entities"] >= 64]
    raw["batch_speedup_at_64"] = float(max(at64))
    print_table(
        "serving: query latency under write trickle",
        ["n_tuples", "queries", "qps", "p50_ms", "p99_ms", "swaps",
         "stale_ms", "consistent"],
        [[f"{n:,}", raw["queries"], f"{raw['qps']:,.0f}",
          f"{raw['p50_ms']:.3f}", f"{raw['p99_ms']:.3f}", raw["swaps"],
          f"{raw['staleness_ms_mean']:.1f}", raw["consistent"]]])
    print_table(
        "serving: batch vs scalar top-k",
        ["entities", "scalar_ms", "batch_ms", "speedup"],
        [[b["entities"], f"{b['scalar_ms']:.2f}", f"{b['batch_ms']:.2f}",
          f"{b['speedup']:.2f}x"] for b in raw["batch"]])
    save_json("serving.json", raw)
    return raw


if __name__ == "__main__":
    run(scale=0.02, repeat=2)
