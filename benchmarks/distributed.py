"""Distributed engine benchmark: replicate vs shuffle merge on a real
multi-device host mesh (the paper's §1 centralise-vs-replicate trade).

Runs in a subprocess with 8 forced host devices (the parent process has
already locked jax to 1 device); reports per-strategy wall time and the
collective schedule from the lowered HLO — the triclustering §Perf cell.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import print_table, save_json

_WORKER = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
import jax
from repro.core import (BatchMiner, DistributedMiner, NOACMiner, pad_tuples,
                        pad_values)
from repro.data import synthetic
from repro.launch.mesh import make_mesh
from repro.analysis.hlo import profile_module

ctx = synthetic.movielens_like(n_tuples=int(%(n)d), seed=0)
mesh = make_mesh((8,), ("data",))
tuples = pad_tuples(ctx.tuples, 8)
out = {}
bm = BatchMiner(ctx.sizes)
r = bm(tuples); jax.block_until_ready(r.sig_lo)
t0 = time.perf_counter(); r = bm(tuples); jax.block_until_ready(r.sig_lo)
out["batch_1dev_ms"] = (time.perf_counter() - t0) * 1e3
for strategy in ("replicate", "shuffle"):
    dm = DistributedMiner(ctx.sizes, mesh, axes="data", strategy=strategy)
    r = dm(tuples); jax.block_until_ready(r.sig_lo)
    t0 = time.perf_counter(); r = dm(tuples); jax.block_until_ready(r.sig_lo)
    ms = (time.perf_counter() - t0) * 1e3
    prof = None
    try:
        lowered = dm.lowered(tuples)
        prof = profile_module(lowered.compile().as_text(), 8)
    except Exception:
        pass
    out[strategy] = {"ms": ms,
                     "n_clusters": int(np.asarray(r.is_unique).sum()),
                     "overflow": int(getattr(r, "overflow", 0))}
    if prof is not None:
        out[strategy]["collectives"] = {k: list(v)
                                        for k, v in prof.by_kind.items()}
        out[strategy]["coll_operand_bytes"] = prof.operand_bytes
        out[strategy]["coll_wire_bytes"] = prof.wire_bytes
# NOAC (many-valued) through the same distributed pipeline
vctx = synthetic.movielens_like(n_tuples=int(%(n)d), seed=0,
                                values=True).deduplicated()
out["noac_n_tuples"] = int(vctx.num_tuples)
vt = pad_tuples(vctx.tuples, 8); vv = pad_values(vctx.values, 8)
nm = NOACMiner(vctx.sizes, delta=1.0)
r = nm(vt, vv); jax.block_until_ready(r.sig_lo)
t0 = time.perf_counter(); r = nm(vt, vv); jax.block_until_ready(r.sig_lo)
out["noac_batch_1dev_ms"] = (time.perf_counter() - t0) * 1e3
for strategy in ("replicate", "shuffle"):
    dm = DistributedMiner(vctx.sizes, mesh, axes="data", strategy=strategy,
                          delta=1.0)
    r = dm(vt, vv); jax.block_until_ready(r.sig_lo)
    t0 = time.perf_counter(); r = dm(vt, vv); jax.block_until_ready(r.sig_lo)
    out["noac_" + strategy] = {
        "ms": (time.perf_counter() - t0) * 1e3,
        "n_clusters": int(np.asarray(r.is_unique).sum()),
        "overflow": int(getattr(r, "overflow", 0))}
print("RESULT " + json.dumps(out))
'''


def run(n_tuples: int = 40_000):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ,
           "PYTHONPATH": os.path.join(root, "src")
           + os.pathsep + os.environ.get("PYTHONPATH", "")}
    proc = subprocess.run([sys.executable, "-c", _WORKER % {"n": n_tuples}],
                          capture_output=True, text=True, env=env,
                          timeout=1200)
    out = {}
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            out = json.loads(line[len("RESULT "):])
    if not out:
        print(proc.stdout[-2000:])
        print(proc.stderr[-2000:])
        raise RuntimeError("distributed benchmark worker failed")
    rows = [["batch (1 dev)", f"{out['batch_1dev_ms']:.1f}", "-", "-"]]
    for s in ("replicate", "shuffle"):
        d = out[s]
        rows.append([s, f"{d['ms']:.1f}", f"{d['n_clusters']:,}",
                     f"{d.get('coll_wire_bytes', 0) / 1e6:.2f}MB"])
    rows.append(["noac batch (1 dev)", f"{out['noac_batch_1dev_ms']:.1f}",
                 "-", "-"])
    for s in ("replicate", "shuffle"):
        d = out[f"noac_{s}"]
        rows.append([f"noac {s}", f"{d['ms']:.1f}", f"{d['n_clusters']:,}",
                     "-"])
    print_table(f"Distributed mining, 8-device mesh, |I|={n_tuples:,}",
                ["engine", "ms", "#clusters", "collective wire"], rows)
    save_json("distributed.json", out)
    return out


if __name__ == "__main__":
    run()
