"""Sort-backend comparison on the packed-key hot path: lexsort vs
packed-lax vs packed-radix, end-to-end, per-stage, per-engine, per
radix pass — plus the run-store section (``core.runs``): out-of-core
chunked Stage 1 vs in-core at equal T, and incremental distributed
snapshots vs full re-sorts under a trickle, and a fixed scale-
independent calibration probe so cross-PR ratios can be normalised on
a noisy machine.

The tentpole comparison of the radix subsystem (``core.radix``): the
same pipeline run three ways on the MovieLens-like dataset — the
N+1-column lexsort baseline (``packed=False``), the packed single
``lax.sort`` (``sort_backend='lax'``), and the bit-plan-pruned LSD
radix default (``sort_backend='radix'``) — for both the prime and the
NOAC (δ) variants, plus batch/streaming engine rows, a per-stage
timing breakdown (Stage 1 split into the sort itself vs the
backend-independent segment work, Stage 2 components, Stage 3 dedup)
and the radix path's per-pass attribution (cumulative truncated
pass schedules).  Many-valued runs pack with the cardinality-pruned
value lane (``core.keys`` value_slots), the engines' default.  All paths produce bit-identical results (asserted by
``tests/test_radix_property.py``); only the time differs.

All probes of one variant are timed *interleaved* (round-robin,
best-of-``repeat`` per probe) so a drifting machine load skews every
path equally instead of whichever happened to run later.
"""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import BatchMiner, DistributedMiner, NOACMiner, StreamingMiner
from repro.core import keys as KY
from repro.core import pipeline as P
from repro.core import radix as RX
from repro.data import synthetic
from repro.launch.mesh import make_local_mesh

from .common import print_table, save_json

DATASET = "movielens-like"
DELTA = 1.0
#: sort_path row label -> engine kwargs
PATHS = {
    "lexsort": {"packed": False},
    "packed-lax": {"sort_backend": "lax"},
    "packed-radix": {"sort_backend": "radix"},
}


def _interleaved_best(probes: dict, repeat: int) -> dict:
    """Best-of-``repeat`` wall time per probe, measured round-robin."""
    import jax
    for fn in probes.values():          # compile everything first
        jax.block_until_ready(fn())
    best = {k: float("inf") for k in probes}
    for _ in range(repeat):
        for k, fn in probes.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], time.perf_counter() - t0)
    return {k: v * 1e3 for k, v in best.items()}


def _value_domain(values):
    """Sorted distinct values (the lane-pruning domain) — hoisted out of
    every timed probe.  (The engines recompute it per public call — a
    one-off host ``np.unique`` on the untransferred column — but the
    probes compare *sort backends*, so the shared domain prep stays
    outside the clock for every path equally.)"""
    if values is None:
        return None
    return KY.value_domain_host(values)


def _stage_probes(sizes, tuples, values, delta, path, use_pallas):
    """Cumulative-stage jitted probes (sort only; + segment; + components;
    full pipeline), all on the same kernel path (``use_pallas``).

    The ``s0`` probe times exactly what the sort backend swaps — key
    packing + the stable word sort (or the column lexsort) per mode —
    while ``s1`` adds the backend-independent segment/inverse-perm work,
    so ``stage1_sort_ms`` attributes the subsystem and not its
    neighbours."""
    import jax
    import jax.numpy as jnp
    kw = PATHS[path]
    backend = RX.resolve_sort_backend(kw.get("sort_backend"),
                                      kw.get("packed"), True)
    vecs = P.mode_hash_vectors(sizes)
    lo = [jnp.asarray(a) for a, _ in vecs]
    hi = [jnp.asarray(b) for _, b in vecs]
    domain = _value_domain(values)
    plans = KY.plan_context_keys(
        sizes, with_values=values is not None,
        value_slots=None if domain is None else domain.shape[0])
    use_packed = backend != "lexsort" and plans[0].fits
    n = tuples.shape[1]
    tuples = jnp.asarray(tuples)
    values = jnp.asarray(values) if values is not None else None
    vdom = jnp.asarray(domain) if domain is not None else None

    def sort_only(tu, va):
        # P.mode_sort_perm IS the pipeline's Stage-1 sort (sort_mode
        # delegates to it), so this probe can never drift from what the
        # engines actually run
        return [P.mode_sort_perm(tu, k, values=va,
                                 plan=plans[k] if use_packed else None,
                                 sort_backend=backend,
                                 use_pallas=use_pallas,
                                 value_domain=vdom)[0]
                for k in range(n)]

    def sort_stage(tu, va):
        return [P.sort_mode(tu, k, values=va,
                            plan=plans[k] if use_packed else None,
                            sort_backend=backend, use_pallas=use_pallas,
                            value_domain=vdom)
                for k in range(n)]

    def comp_stage(tu, va):
        comps = []
        for k, sm in enumerate(sort_stage(tu, va)):
            if delta is None:
                comps.append(P.prime_components(sm, lo[k], hi[k],
                                                use_pallas))
            else:
                comps.append(P.delta_components(sm, lo[k], hi[k], va, delta,
                                                use_pallas,
                                                value_domain=vdom))
        return P.mix_signatures([c.sig_lo for c in comps],
                                [c.sig_hi for c in comps])

    f0 = jax.jit(sort_only)
    f1 = jax.jit(lambda tu, va: [(sm.perm, sm.seg_a, sm.seg_b, sm.first_occ)
                                 for sm in sort_stage(tu, va)])
    f12 = jax.jit(comp_stage)
    full = jax.jit(functools.partial(P.mine_tuples, delta=delta,
                                     use_pallas=use_pallas, **kw))
    return {"s0": lambda: f0(tuples, values),
            "s1": lambda: f1(tuples, values),
            "s12": lambda: f12(tuples, values),
            "full": lambda: full(tuples, lo, hi, values=values,
                                 value_domain=vdom)}


def _radix_pass_probes(sizes, tuples, values, use_pallas):
    """Truncated-schedule probes: all modes packed + radix-sorted with
    only the first p LSD passes, p = 0..npass (p=0 times the packing
    alone) — the per-pass attribution of the radix backend."""
    import jax
    import jax.numpy as jnp
    domain = _value_domain(values)
    plans = KY.plan_context_keys(
        sizes, with_values=values is not None,
        value_slots=None if domain is None else domain.shape[0])
    if not plans[0].fits:
        return {}, None
    # the attribution schedule must match the formulation actually run:
    # composite-word digits on CPU, 8-bit histogram digits under Pallas
    rplan = RX.plan_radix(plans[0].total_bits, tuples.shape[0],
                          digit_bits=(RX.HIST_DIGIT_BITS if use_pallas
                                      else None))
    tuples = jnp.asarray(tuples)
    values = jnp.asarray(values) if values is not None else None
    vdom = jnp.asarray(domain) if domain is not None else None

    def run(tu, va, p):
        out = []
        for plan in plans:
            words = plan.pack_device(tu, va, domain=vdom)
            out.append(words if p == 0 else
                       RX.radix_sort_perm(words, plan.total_bits,
                                          use_pallas, max_passes=p))
        return out

    probes = {p: jax.jit(functools.partial(run, p=p))
              for p in range(rplan.passes + 1)}
    return ({p: functools.partial(fn, tuples, values)
             for p, fn in probes.items()}, rplan)


def calibration_probe(repeat: int = 5) -> dict:
    """Fixed machine-speed probe (ROADMAP "benchmark hygiene"): one
    device radix sort of the SAME 100k uint32 words every PR (fixed
    Philox seed, independent of ``--scale``), best-of-``repeat``.
    Cross-PR ratios divide by this to normalise a ±30%-noisy machine."""
    import jax
    import jax.numpy as jnp
    rng = np.random.Generator(np.random.Philox(0xCA11B))
    words = jnp.asarray(rng.integers(0, 2**32, 100_000, dtype=np.uint32))
    fn = jax.jit(lambda w: RX.radix_sort_perm((w,), 32))
    best = _interleaved_best({"probe": lambda: fn(words)}, repeat)
    return {"probe": "radix_sort_perm_100k_u32", "n": 100_000,
            "ms": best["probe"]}


def _runs_section(sizes, tuples, values, delta, variant, repeat,
                  use_pallas, rows_out, rows_disp):
    """Run-store section (``core.runs``): out-of-core chunked Stage 1
    vs in-core end-to-end at equal T, and incremental distributed
    snapshots (per-shard run merges) vs full re-sort snapshots under a
    trickle of new tuples.  Probes of one pair are interleaved like the
    sort-path probes."""
    n = tuples.shape[0]
    kw = {} if delta is None else {"delta": delta}
    # -- out-of-core vs in-core, equal T ------------------------------------
    bm = (BatchMiner(sizes, use_pallas=use_pallas) if delta is None
          else NOACMiner(sizes, delta=delta, use_pallas=use_pallas))
    budget = -(-n // 6)     # 6 host-sorted chunks
    probes = {
        "in_core": (lambda: bm(tuples) if values is None
                    else bm(tuples, values)),
        "out_of_core": lambda: bm.mine_chunked(
            tuples, values=values, chunk_budget=budget),
    }
    best = _interleaved_best(probes, repeat)
    for mode in ("in_core", "out_of_core"):
        rows_out.append({"backend": "batch", "variant": variant,
                         "dataset": DATASET, "mode": mode,
                         "n_tuples": int(n), "ms": best[mode]})
        rows_disp.append([variant, "batch", mode, f"{n:,}",
                          f"{best[mode]:,.1f}", ""])
    ooc = {"out_of_core": best["in_core"] / max(best["out_of_core"], 1e-9)}
    # -- incremental distributed snapshots vs full re-sorts -----------------
    mesh = make_local_mesh()
    miners = {m: DistributedMiner(sizes, mesh, use_pallas=use_pallas, **kw)
              for m in ("incremental", "full_resort")}
    # the baseline must not pay run maintenance it then discards:
    # log-only stores, every snapshot a device re-sort
    miners["full_resort"].stream_incremental = False
    chunk = -(-n // 8)
    trickle = max(1, n // 200)       # the "new tuples" between snapshots
    for m, dm in miners.items():
        for lo in range(0, n, chunk):   # preload the stream
            dm.ingest(tuples[lo:lo + chunk],
                      None if values is None else values[lo:lo + chunk])
        dm.snapshot(full_remine=(m == "full_resort"))   # warm compile

    def snap(m):
        dm = miners[m]
        dm.ingest(tuples[:trickle],
                  None if values is None else values[:trickle])
        return dm.snapshot(full_remine=(m == "full_resort"))

    best = _interleaved_best(
        {m: functools.partial(snap, m) for m in miners}, repeat)
    for m in miners:
        rows_out.append({"backend": "distributed", "variant": variant,
                         "dataset": DATASET, "mode": m,
                         "n_tuples": int(n), "ms": best[m]})
        rows_disp.append([variant, "distributed", m, f"{n:,}",
                          f"{best[m]:,.1f}", ""])
    ooc["incremental_snapshot"] = (best["full_resort"]
                                   / max(best["incremental"], 1e-9))
    return ooc


def _windowed_section(sizes, tuples, values, delta, variant, repeat,
                      use_pallas, rows_disp):
    """Windowed device pipeline (``core.windowed``, DESIGN.md §3c): the
    same table mined monolithically vs streamed through bounded
    sorted-order windows — bit-identity, throughput at equal in-core T
    (``window_budget=T`` is a single window holding the whole table),
    and peak incremental device allocation at ``budget = ceil(T/8)``
    (≥ 8 windows, i.e. a table ≥ 8× the window budget mined on-device).

    The peak probe runs OUTSIDE the timed probes: the monolithic run
    keeps O(T) device result leaves resident, while the windowed run's
    device high-water is O(window) + O(n_clusters) (its result is
    host-side numpy)."""
    import dataclasses

    import jax

    from repro.core import memprobe as MP
    n = int(tuples.shape[0])
    wplan = RX.plan_windows(n, -(-n // 8))
    miner = (BatchMiner(sizes, use_pallas=use_pallas) if delta is None
             else NOACMiner(sizes, delta=delta, use_pallas=use_pallas))
    call = ((lambda: miner(tuples)) if values is None
            else (lambda: miner(tuples, values)))
    best = _interleaved_best({
        "monolithic": call,
        "windowed": lambda: miner.mine_windowed(
            tuples, values=values, window_budget=wplan.budget),
        "equal_budget": lambda: miner.mine_windowed(
            tuples, values=values, window_budget=n),
    }, repeat)
    mono = call()
    win = miner.mine_windowed(tuples, values=values,
                              window_budget=wplan.budget)
    identical = all(
        np.array_equal(np.asarray(getattr(mono, f.name)),
                       np.asarray(getattr(win, f.name)))
        for f in dataclasses.fields(mono))
    del mono, win
    probe_m = MP.MemProbe()
    mono = jax.block_until_ready(call())
    probe_m("monolithic")
    peak_mono = max(probe_m.peak_bytes, MP.measure_result_bytes(mono))
    del mono
    probe_w = MP.MemProbe()
    miner.mine_windowed(tuples, values=values, window_budget=wplan.budget,
                        probe=probe_w)
    peak_win = max(probe_w.peak_bytes, 1)
    sec = {
        "n_tuples": n, "window_budget": int(wplan.budget),
        "n_windows": int(wplan.n_windows),
        "bit_identical": bool(identical),
        "monolithic_ms": best["monolithic"],
        "windowed_ms": best["windowed"],
        "equal_budget_ms": best["equal_budget"],
        # the ≥0.8× gate: a single table-sized window vs monolithic —
        # equal in-core T, so the ratio isolates the windowed driver's
        # overhead rather than the (intentional) cost of small windows
        "throughput_ratio": best["monolithic"] / max(best["equal_budget"],
                                                     1e-9),
        "windowed_ratio": best["monolithic"] / max(best["windowed"], 1e-9),
        "peak_monolithic_bytes": int(peak_mono),
        "peak_windowed_bytes": int(peak_win),
        "peak_ratio": peak_mono / peak_win,
        "stage_peaks": {k: int(v)
                        for k, v in sorted(probe_w.stages.items())},
    }
    rows_disp.append([variant, "batch", f"windowed({wplan.n_windows}w)",
                      f"{n:,}", f"{best['windowed']:,.1f}",
                      f"{sec['peak_ratio']:.1f}x"])
    rows_disp.append([variant, "batch", "windowed(1w)", f"{n:,}",
                      f"{best['equal_budget']:,.1f}",
                      f"{sec['throughput_ratio']:.2f}x"])
    return sec


def run(scale: float = 0.12, repeat: int = 3, use_pallas: bool = False):
    raw = {"rows": [], "speedup": {}, "radix_speedup": {},
           "runs_speedup": {}, "windowed": {},
           "calibration": calibration_probe()}
    full_ctx = synthetic.movielens_like(n_tuples=int(1_000_000 * scale),
                                        seed=0)
    noac_ctx = full_ctx.deduplicated()
    jobs = [
        ("prime", full_ctx.tuples, None, None),
        ("noac", noac_ctx.tuples, noac_ctx.values, DELTA),
    ]
    rows_disp = []
    runs_disp = []
    for variant, tuples, values, delta in jobs:
        n = tuples.shape[0]
        probes = {}
        for path in PATHS:
            for stage, fn in _stage_probes(full_ctx.sizes, tuples, values,
                                           delta, path,
                                           use_pallas).items():
                probes[(path, stage)] = fn
        pass_probes, rplan = _radix_pass_probes(full_ctx.sizes, tuples,
                                                values, use_pallas)
        for p, fn in pass_probes.items():
            probes[("passes", p)] = fn
        best = _interleaved_best(probes, repeat)
        cum = [best[("passes", p)] for p in range(rplan.passes + 1)] \
            if rplan else []
        radix_detail = {
            "passes": rplan.passes, "digit_widths": list(rplan.widths),
            "live_bits": rplan.live_bits, "pos_bits": rplan.pos_bits,
            "pack_ms": cum[0],
            "per_pass_ms": [max(b - a, 0.0)
                            for a, b in zip(cum, cum[1:])],
        } if rplan else None
        for path in PATHS:
            stages = {
                "stage1_sort_ms": best[(path, "s0")],
                "stage1_segment_ms": max(best[(path, "s1")]
                                         - best[(path, "s0")], 0.0),
                "stage2_components_ms": max(best[(path, "s12")]
                                            - best[(path, "s1")], 0.0),
                "stage3_dedup_ms": max(best[(path, "full")]
                                       - best[(path, "s12")], 0.0),
                "total_ms": best[(path, "full")]}
            row = {
                "backend": "batch", "variant": variant, "dataset": DATASET,
                "sort_path": path, "n_tuples": int(n),
                "ms": best[(path, "full")], "stages": stages}
            if path == "packed-radix" and radix_detail:
                row["radix"] = radix_detail
            raw["rows"].append(row)
            rows_disp.append([variant, "batch", path, f"{n:,}",
                              f"{best[(path, 'full')]:,.1f}",
                              f"{stages['stage1_sort_ms']:.1f}"])
        # streaming engine: one full-buffer snapshot per path, interleaved
        sprobes = {}
        for path, kw in PATHS.items():
            sm = StreamingMiner(full_ctx.sizes, delta=delta,
                                use_pallas=use_pallas, incremental=False,
                                **kw)
            sm.add(tuples, values)
            sprobes[path] = functools.partial(sm.snapshot, full_remine=True)
        sbest = _interleaved_best(sprobes, repeat)
        for path, ms in sbest.items():
            raw["rows"].append({
                "backend": "streaming", "variant": variant,
                "dataset": DATASET, "sort_path": path,
                "n_tuples": int(n), "ms": ms})
            rows_disp.append([variant, "streaming", path, f"{n:,}",
                              f"{ms:,.1f}", ""])
        # run-store section: out-of-core + incremental distributed
        raw["runs_speedup"][variant] = _runs_section(
            full_ctx.sizes, tuples, values, delta, variant, repeat,
            use_pallas, raw["rows"], runs_disp)
        # windowed device pipeline: bounded-HBM window streaming vs the
        # monolithic path (bit-identity + throughput + peak allocation)
        raw["windowed"][variant] = _windowed_section(
            full_ctx.sizes, tuples, values, delta, variant, repeat,
            use_pallas, runs_disp)
    # headline ratios: the Stage-1 sort path (the subsystem this PR
    # swaps) and the full pipeline — lexsort vs the packed default
    # (packed_speedup, the PR-2 metric) and packed-lax vs packed-radix
    # (radix_speedup, the comparison-sort replacement itself)
    for variant in ("prime", "noac"):
        by = {r["sort_path"]: r for r in raw["rows"]
              if r["variant"] == variant and r["backend"] == "batch"
              and "sort_path" in r}

        def ratio(a, b, key):
            if key == "ms":
                return by[a]["ms"] / max(by[b]["ms"], 1e-9)
            return (by[a]["stages"][key] / max(by[b]["stages"][key], 1e-9))

        raw["speedup"][variant] = {
            "stage1_sort": ratio("lexsort", "packed-radix",
                                 "stage1_sort_ms"),
            "end_to_end": ratio("lexsort", "packed-radix", "ms")}
        raw["radix_speedup"][variant] = {
            "stage1_sort": ratio("packed-lax", "packed-radix",
                                 "stage1_sort_ms"),
            "end_to_end": ratio("packed-lax", "packed-radix", "ms")}
    print_table("Sort backends: lexsort vs packed-lax vs packed-radix "
                "(movielens-like)",
                ["variant", "backend", "path", "|I|", "ms", "s1 ms"],
                rows_disp)
    print_table("Run store: out-of-core vs in-core, incremental vs "
                "full-re-sort snapshots",
                ["variant", "backend", "mode", "|I|", "ms", ""],
                runs_disp)
    print("packed_speedup (lexsort/packed-radix):",
          {v: {k: round(x, 2) for k, x in d.items()}
           for v, d in raw["speedup"].items()})
    print("radix_speedup (packed-lax/packed-radix):",
          {v: {k: round(x, 2) for k, x in d.items()}
           for v, d in raw["radix_speedup"].items()})
    print("runs_speedup (in-core/out-of-core, full/incremental):",
          {v: {k: round(x, 2) for k, x in d.items()}
           for v, d in raw["runs_speedup"].items()})
    print("windowed (bit_identical, mono/equal-T, peak mono/window):",
          {v: {"bit_identical": d["bit_identical"],
               "n_windows": d["n_windows"],
               "throughput_ratio": round(d["throughput_ratio"], 2),
               "peak_ratio": round(d["peak_ratio"], 1)}
           for v, d in raw["windowed"].items()})
    print("calibration probe:", raw["calibration"])
    save_json("packed.json", raw)
    return raw


if __name__ == "__main__":
    run()
