"""Packed-key vs lexsort sort paths: end-to-end, per-stage, per-engine.

The tentpole comparison of the packed-key subsystem (``core.keys``): the
same pipeline run twice on the MovieLens-like dataset — once with the
single-word packed sort path (``packed=True``) and once with the
N+1-column lexsort baseline (``packed=False``) — for both the prime and
the NOAC (δ) variants, plus the batch/streaming engine rows and a
per-stage timing breakdown (Stage 1 sort+segment, Stage 2 components,
Stage 3 dedup).  Both paths produce bit-identical results (asserted by
``tests/test_keys_property.py``); only the time differs.

All probes of one variant are timed *interleaved* (packed, lexsort,
packed, ... round-robin, best-of-``repeat`` per probe) so a drifting
machine load skews both paths equally instead of whichever happened to
run later.
"""
from __future__ import annotations

import functools
import time

from repro.core import StreamingMiner
from repro.core import keys as KY
from repro.core import pipeline as P
from repro.data import synthetic

from .common import print_table, save_json

DATASET = "movielens-like"
DELTA = 1.0
PATHS = {True: "packed", False: "lexsort"}


def _interleaved_best(probes: dict, repeat: int) -> dict:
    """Best-of-``repeat`` wall time per probe, measured round-robin."""
    import jax
    for fn in probes.values():          # compile everything first
        jax.block_until_ready(fn())
    best = {k: float("inf") for k in probes}
    for _ in range(repeat):
        for k, fn in probes.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], time.perf_counter() - t0)
    return {k: v * 1e3 for k, v in best.items()}


def _stage_probes(sizes, tuples, values, delta, packed, use_pallas):
    """Cumulative-stage jitted probes (sort+segment; + components; full
    pipeline), all on the same kernel path (``use_pallas``)."""
    import jax
    import jax.numpy as jnp
    vecs = P.mode_hash_vectors(sizes)
    lo = [jnp.asarray(a) for a, _ in vecs]
    hi = [jnp.asarray(b) for _, b in vecs]
    plans = KY.plan_context_keys(sizes, with_values=values is not None)
    use_packed = packed and plans[0].fits
    n = tuples.shape[1]
    tuples = jnp.asarray(tuples)
    values = jnp.asarray(values) if values is not None else None

    def sort_stage(tu, va):
        return [P.sort_mode(tu, k, values=va,
                            plan=plans[k] if use_packed else None)
                for k in range(n)]

    def comp_stage(tu, va):
        comps = []
        for k, sm in enumerate(sort_stage(tu, va)):
            if delta is None:
                comps.append(P.prime_components(sm, lo[k], hi[k],
                                                use_pallas))
            else:
                comps.append(P.delta_components(sm, lo[k], hi[k], va, delta,
                                                use_pallas))
        return P.mix_signatures([c.sig_lo for c in comps],
                                [c.sig_hi for c in comps])

    f1 = jax.jit(lambda tu, va: [(sm.perm, sm.seg_a, sm.seg_b, sm.first_occ)
                                 for sm in sort_stage(tu, va)])
    f12 = jax.jit(comp_stage)
    full = jax.jit(functools.partial(P.mine_tuples, delta=delta,
                                     packed=packed, use_pallas=use_pallas))
    return {"s1": lambda: f1(tuples, values),
            "s12": lambda: f12(tuples, values),
            "full": lambda: full(tuples, lo, hi, values=values)}


def run(scale: float = 0.12, repeat: int = 3, use_pallas: bool = False):
    raw = {"rows": [], "speedup": {}}
    full_ctx = synthetic.movielens_like(n_tuples=int(1_000_000 * scale),
                                        seed=0)
    noac_ctx = full_ctx.deduplicated()
    jobs = [
        ("prime", full_ctx.tuples, None, None),
        ("noac", noac_ctx.tuples, noac_ctx.values, DELTA),
    ]
    rows_disp = []
    for variant, tuples, values, delta in jobs:
        n = tuples.shape[0]
        probes = {}
        for packed, path in PATHS.items():
            for stage, fn in _stage_probes(full_ctx.sizes, tuples, values,
                                           delta, packed,
                                           use_pallas).items():
                probes[(path, stage)] = fn
        best = _interleaved_best(probes, repeat)
        for path in PATHS.values():
            stages = {
                "stage1_sort_ms": best[(path, "s1")],
                "stage2_components_ms": max(best[(path, "s12")]
                                            - best[(path, "s1")], 0.0),
                "stage3_dedup_ms": max(best[(path, "full")]
                                       - best[(path, "s12")], 0.0),
                "total_ms": best[(path, "full")]}
            raw["rows"].append({
                "backend": "batch", "variant": variant, "dataset": DATASET,
                "sort_path": path, "n_tuples": int(n),
                "ms": best[(path, "full")], "stages": stages})
            rows_disp.append([variant, "batch", path, f"{n:,}",
                              f"{best[(path, 'full')]:,.1f}",
                              f"{stages['stage1_sort_ms']:.1f}"])
        # streaming engine: one full-buffer snapshot per path, interleaved
        sprobes = {}
        for packed, path in PATHS.items():
            sm = StreamingMiner(full_ctx.sizes, packed=packed, delta=delta,
                                use_pallas=use_pallas, incremental=False)
            sm.add(tuples, values)
            sprobes[path] = functools.partial(sm.snapshot, full_remine=True)
        sbest = _interleaved_best(sprobes, repeat)
        for path, ms in sbest.items():
            raw["rows"].append({
                "backend": "streaming", "variant": variant,
                "dataset": DATASET, "sort_path": path,
                "n_tuples": int(n), "ms": ms})
            rows_disp.append([variant, "streaming", path, f"{n:,}",
                              f"{ms:,.1f}", ""])
    # headline ratios: the sort path itself (Stage 1, the subsystem this
    # PR swaps) and the full pipeline
    for variant in ("prime", "noac"):
        by = {r["sort_path"]: r for r in raw["rows"]
              if r["variant"] == variant and r["backend"] == "batch"}
        raw["speedup"][variant] = {
            "stage1_sort": (by["lexsort"]["stages"]["stage1_sort_ms"]
                            / max(by["packed"]["stages"]["stage1_sort_ms"],
                                  1e-9)),
            "end_to_end": by["lexsort"]["ms"] / max(by["packed"]["ms"],
                                                    1e-9)}
    print_table("Packed-key vs lexsort (movielens-like)",
                ["variant", "backend", "path", "|I|", "ms", "s1 ms"],
                rows_disp)
    print("speedups:", {v: {k: round(x, 2) for k, x in d.items()}
                        for v, d in raw["speedup"].items()})
    save_json("packed.json", raw)
    return raw


if __name__ == "__main__":
    run()
