"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def timeit(fn, *args, repeat: int = 3, **kw):
    """(best_seconds, last_result) — paper protocol: best of N runs."""
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        _block(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def save_json(name: str, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


def print_table(title: str, headers: list, rows: list):
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    print("  ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
