"""Paper Table 3: online OAC-prime vs the three-stage multimodal pipeline.

Datasets: IMDB-like, MovieLens100k-like, K1 (dense 60³−diag), K2 (three
50³ cuboids), K3 (dense 30⁴). The paper's "online" column is the
sequential dict-based Alg. 1 (``core.reference.OnlineOACPrime``); the M/R
column is our batch/mesh pipeline (``core.batch.BatchMiner``) — same
three conceptual stages as the Hadoop version, executed as sort-segment
kernels instead of shuffles. Sizes are scaled-down-compatible (CPU budget)
via --scale; counts are exact and cross-checked between both engines.
"""
from __future__ import annotations

import numpy as np

from repro.core import BatchMiner
from repro.core.reference import multimodal_clusters
from repro.data import synthetic as S

from .common import print_table, save_json, timeit


def datasets(scale: float = 1.0):
    n1 = max(8, int(60 * scale))
    n2 = max(8, int(50 * scale))
    n3 = max(6, int(30 * scale))
    return [
        ("IMDB", S.imdb_like(seed=0)),
        ("MovieLens100k", S.movielens_like(
            n_tuples=int(100_000 * scale * scale), seed=0)),
        ("K1", S.k1_dense_cube(n1)),
        ("K2", S.k2_three_cuboids(n2)),
        ("K3", S.k3_dense_4d(n3)),
    ]


def run(scale: float = 0.35, repeat: int = 3):
    rows, raw = [], {}
    for name, ctx in datasets(scale):
        # "online" column: the sequential dict-per-mode 1-pass engine
        # (paper Alg. 1 generalised to N-ary — same data structures)
        t_on, on_out = timeit(lambda: multimodal_clusters(ctx), repeat=1)
        miner = BatchMiner(ctx.sizes)
        miner(ctx.tuples[: min(64, len(ctx.tuples))])      # warm compile
        t_mr, res = timeit(miner, ctx.tuples, repeat=repeat)
        n_on = len(on_out[1])
        n_mr = int(np.asarray(res.is_unique).sum())
        rows.append([name, f"{len(ctx.tuples):,}", f"{t_on * 1e3:,.0f}",
                     f"{t_mr * 1e3:,.0f}", f"{t_on / t_mr:.1f}x",
                     n_on, n_mr, "OK" if n_on == n_mr else "MISMATCH"])
        raw[name] = {"triples": len(ctx.tuples), "online_ms": t_on * 1e3,
                     "pipeline_ms": t_mr * 1e3, "clusters": n_mr}
    print_table("Table 3 — online vs three-stage pipeline (ms)",
                ["dataset", "|I|", "online", "pipeline", "speedup",
                 "#cl(online)", "#cl(pipeline)", "check"], rows)
    save_json("table3.json", raw)
    return raw


if __name__ == "__main__":
    run()
