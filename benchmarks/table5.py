"""Paper Table 5 / Fig. 3: NOAC (many-valued δ-triclustering), sequential
vs parallel, on the semantic-frames-like dataset.

The paper compares single-thread vs C# Parallel over triples. Our
"parallel" is the jit-vectorised NOAC engine over all devices;
"sequential" is the pure-python reference (same δ/ρ/minsup semantics).
Both parameterisations from the paper: NOAC(100, 0.8, 2), NOAC(100, 0.5, 0).
"""
from __future__ import annotations

import numpy as np

from repro.core import NOACMiner
from repro.core import reference as R
from repro.data import synthetic as S

from .common import print_table, save_json, timeit


def run(scale: float = 0.05, repeat: int = 3):
    full = S.semantic_frames_like(n_tuples=int(100_000 * scale), seed=0)
    params = [(100.0, 0.8, 2), (100.0, 0.5, 0)]
    steps = [max(int(f * full.tuples.shape[0]), 32)
             for f in (0.1, 0.5, 1.0)]
    import dataclasses as dc
    rows, raw = [], []
    for delta, rho, minsup in params:
        for n in steps:
            tuples = full.tuples[:n]
            vals = (full.values[:n] if full.values is not None
                    else np.ones(n, np.float32))
            subctx = dc.replace(full, tuples=tuples, values=vals)
            t_seq, seq_out = timeit(
                lambda: R.noac(subctx, delta, rho, minsup), repeat=1)
            miner = NOACMiner(full.sizes, delta=delta, rho_min=rho,
                              minsup=minsup)
            t_par, res = timeit(miner, tuples, vals, repeat=repeat)
            n_seq = len(seq_out)
            n_par = int(np.asarray(res.keep).sum())
            rows.append([f"NOAC({delta:.0f},{rho},{minsup}) {n}",
                         f"{t_seq * 1e3:,.0f}", f"{t_par * 1e3:,.0f}",
                         f"{t_seq / max(t_par, 1e-9):.1f}x",
                         n_seq, n_par,
                         "OK" if n_seq == n_par else "MISMATCH"])
            raw.append({"delta": delta, "rho": rho, "minsup": minsup,
                        "n": n, "seq_ms": t_seq * 1e3, "par_ms": t_par * 1e3,
                        "clusters": n_par})
    print_table("Table 5 — NOAC sequential vs vectorised (ms)",
                ["experiment", "seq", "parallel", "speedup",
                 "#cl(seq)", "#cl(par)", "check"], rows)
    save_json("table5.json", raw)
    return raw


if __name__ == "__main__":
    run()
