"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (+ the distributed mesh benchmark).
``--scale`` shrinks dataset sizes to the CPU budget (default settings
finish in a few minutes on one core); every run saves raw JSON under
results/.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12,
                    help="dataset size multiplier vs the paper's")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--only", default="",
                    help="comma list: table3,table4,table5,scaling,"
                    "distributed")
    args = ap.parse_args(argv)

    from . import distributed, scaling, table3, table4, table5
    jobs = {
        "table3": lambda: table3.run(scale=args.scale * 3,
                                     repeat=args.repeat),
        "table4": lambda: table4.run(scale=args.scale, repeat=args.repeat),
        "table5": lambda: table5.run(scale=args.scale / 2,
                                     repeat=args.repeat),
        "scaling": lambda: scaling.run(scale=args.scale,
                                       repeat=args.repeat),
        "distributed": lambda: distributed.run(
            n_tuples=int(320_000 * args.scale)),
    }
    only = [s for s in args.only.split(",") if s] or list(jobs)
    rc = 0
    for name in only:
        print(f"\n######## {name} ########", flush=True)
        try:
            jobs[name]()
        except Exception:
            traceback.print_exc()
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
