"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (+ the distributed mesh benchmark).
``--scale`` shrinks dataset sizes to the CPU budget (default settings
finish in a few minutes on one core); every run saves raw JSON under
results/, plus a machine-readable ``BENCH_mining.json`` summary with
per-backend/variant wall-time and tuples/sec so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def _mining_summary(results: dict, scale: float) -> dict:
    """Normalise each job's raw output to rows of
    {backend, variant, dataset, n_tuples, ms, tuples_per_s}."""
    rows = []

    def row(backend, variant, dataset, n, ms, **extra):
        if not n or ms is None:
            return
        rows.append({"backend": backend, "variant": variant,
                     "dataset": dataset, "n_tuples": int(n),
                     "ms": float(ms),
                     "tuples_per_s": float(n) / (float(ms) / 1e3)
                     if ms else 0.0, **extra})

    for r in (results.get("table4") or {}).values():
        row("batch", "prime", "movielens-like", r["tuples"], r["total_ms"])
    for r in (results.get("scaling") or {}).get("fig2", []):
        row("batch", "prime", "movielens-like", r["n"], r["ms"])
    for r in (results.get("scaling") or {}).get("fig3", []):
        row("batch", "noac", "frames-like", r["n"], r["ms"],
            params=r.get("params"))
    for r in (results.get("scaling") or {}).get("noac_distributed", []):
        row("distributed", "noac", "frames-like", r["n"], r["ms"],
            strategy=r["strategy"])
    for r in (results.get("scaling") or {}).get("streaming", []):
        row("streaming", "prime", "movielens-like", r["n"],
            r["mean_snapshot_ms"], mode=r["mode"],
            snapshots=r["snapshots"])
    for r in (results.get("table5") or []):
        row("batch", "noac", "frames-like", r["n"], r["par_ms"])
        row("reference", "noac", "frames-like", r["n"], r["seq_ms"])
    for r in (results.get("packed") or {}).get("rows", []):
        row(r["backend"], r["variant"], r["dataset"], r["n_tuples"],
            r["ms"],
            **{k: r[k] for k in ("sort_path", "stages", "radix", "mode")
               if k in r})
    dist = results.get("distributed") or {}
    for strategy in ("replicate", "shuffle"):
        for variant, key in (("prime", strategy), ("noac",
                                                   f"noac_{strategy}")):
            d = dist.get(key)
            if d:
                n = (dist.get("noac_n_tuples") if variant == "noac"
                     else dist.get("n_tuples"))  # noac mines deduplicated
                row("distributed", variant, "movielens-like", n, d["ms"],
                    strategy=strategy, devices=8)
    out = {"scale": scale, "rows": rows}
    if results.get("packed"):
        # headline sort-path ratios (Stage-1 sort and end-to-end),
        # movielens-like, both variants: lexsort vs the packed default
        # and packed-lax vs packed-radix (the comparison-sort swap)
        out["packed_speedup"] = results["packed"]["speedup"]
        out["radix_speedup"] = results["packed"]["radix_speedup"]
        # run-store ratios (out-of-core overhead, incremental snapshot
        # gain) + the fixed machine-speed probe for cross-PR
        # normalisation (ROADMAP benchmark hygiene)
        out["runs_speedup"] = results["packed"]["runs_speedup"]
        out["calibration"] = results["packed"]["calibration"]
        # windowed device pipeline (DESIGN.md §3c): bit-identity +
        # equal-T throughput + peak-allocation ratios, schema-gated by
        # benchmarks/validate.py (older raw docs lack the section)
        if results["packed"].get("windowed"):
            out["windowed"] = results["packed"]["windowed"]
    if results.get("serving"):
        # online query service: latency under a write trickle, swap
        # staleness, batch-vs-scalar speedup (benchmarks/serving.py);
        # the sharded-plane results (delta index rebuild, replica
        # scale-out) are their own gated section
        srv = dict(results["serving"])
        scale_sec = srv.pop("serving_scale", None)
        obs_sec = srv.pop("serving_obs", None)
        out["serving"] = srv
        if scale_sec:
            out["serving_scale"] = scale_sec
        # observability instrumentation overhead (DESIGN.md §11):
        # metrics-on vs metrics-off query p50 and snapshot-swap
        # latency, gated <= 3% at report scale by validate.py
        if obs_sec:
            out["serving_obs"] = obs_sec
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12,
                    help="dataset size multiplier vs the paper's")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--only", default="",
                    help="comma list: table3,table4,table5,scaling,"
                    "distributed,packed,serving")
    ap.add_argument("--out", default="BENCH_mining.json",
                    help="summary filename under results/ (smoke runs "
                    "should not overwrite the tracked full-scale file)")
    args = ap.parse_args(argv)

    from . import distributed, packed, scaling, serving, table3, table4, \
        table5
    from .common import save_json
    n_dist = int(320_000 * args.scale)
    jobs = {
        "table3": lambda: table3.run(scale=args.scale * 3,
                                     repeat=args.repeat),
        "table4": lambda: table4.run(scale=args.scale, repeat=args.repeat),
        "table5": lambda: table5.run(scale=args.scale / 2,
                                     repeat=args.repeat),
        "scaling": lambda: scaling.run(scale=args.scale,
                                       repeat=args.repeat),
        "distributed": lambda: distributed.run(n_tuples=n_dist),
        "packed": lambda: packed.run(scale=args.scale, repeat=args.repeat),
        "serving": lambda: serving.run(scale=args.scale,
                                       repeat=args.repeat),
    }
    only = [s for s in args.only.split(",") if s] or list(jobs)
    rc = 0
    results = {}
    for name in only:
        print(f"\n######## {name} ########", flush=True)
        try:
            results[name] = jobs[name]()
        except Exception:
            traceback.print_exc()
            rc = 1
    if results.get("distributed") is not None:
        results["distributed"]["n_tuples"] = n_dist
    path = save_json(args.out, _mining_summary(results, args.scale))
    print(f"\n[bench] wrote {path}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
