"""Paper Fig. 2/3: runtime-vs-|I| scaling curves, plus the unified-engine
extensions: NOAC on the distributed backend and incremental-vs-full
streaming snapshots.

Fig. 2 analogue: pipeline time as a function of tuple count on the
MovieLens-like stream (expects ~linear — the paper's O(|I|·Σ|A_j|)).
Fig. 3 analogue: NOAC time vs tuple count (two parameterisations,
expecting parameter-independence of runtime, the paper's observation).
NOAC-distributed: the same δ-pipeline through ``shard_map`` (replicate
and shuffle merge) on the local mesh — the paper's §6 scale-out cell.
Streaming: amortised snapshot cost, merge-based incremental vs full
re-mine of the buffer, at several chunk boundaries.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import BatchMiner, NOACMiner, StreamingMiner, mine
from repro.data import synthetic as S

from .common import print_table, save_json, timeit


def run(scale: float = 0.2, repeat: int = 3):
    raw = {"fig2": [], "fig3": [], "noac_distributed": [], "streaming": []}
    full = S.movielens_like(n_tuples=int(1_000_000 * scale), seed=0)
    fracs = (0.1, 0.25, 0.5, 0.75, 1.0)
    miner = BatchMiner(full.sizes)
    rows = []
    for f in fracs:
        n = max(int(full.tuples.shape[0] * f), 64)
        t, res = timeit(miner, full.tuples[:n], repeat=repeat)
        n_cl = int(np.asarray(res.is_unique).sum())
        rows.append([f"{n:,}", f"{t * 1e3:,.1f}", f"{n_cl:,}",
                     f"{t / n * 1e6:.2f}"])
        raw["fig2"].append({"n": n, "ms": t * 1e3, "clusters": n_cl})
    print_table("Fig. 2 — pipeline scaling (MovieLens-like)",
                ["|I|", "ms", "#clusters", "µs/tuple"], rows)

    frames = S.semantic_frames_like(n_tuples=int(100_000 * scale), seed=0)
    rows = []
    for delta, rho, minsup in [(100.0, 0.8, 2), (100.0, 0.5, 0)]:
        nm = NOACMiner(frames.sizes, delta=delta, rho_min=rho, minsup=minsup)
        for f in fracs:
            n = max(int(frames.tuples.shape[0] * f), 64)
            vals = frames.values[:n]
            t, res = timeit(nm, frames.tuples[:n], vals, repeat=repeat)
            rows.append([f"NOAC({delta:.0f},{rho},{minsup})", f"{n:,}",
                         f"{t * 1e3:,.1f}",
                         int(np.asarray(res.keep).sum())])
            raw["fig3"].append({"params": [delta, rho, minsup], "n": n,
                                "ms": t * 1e3})
    print_table("Fig. 3 — NOAC scaling (frames-like)",
                ["params", "|I|", "ms", "#kept"], rows)

    # -- NOAC through the distributed engine (unified pipeline) -------------
    import dataclasses as dc
    rows = []
    for strategy in ("replicate", "shuffle"):
        for f in (0.25, 1.0):
            n_raw = max(int(frames.tuples.shape[0] * f), 64)
            sub = dc.replace(frames, tuples=frames.tuples[:n_raw],
                             values=frames.values[:n_raw]).deduplicated()
            n = sub.num_tuples  # what the engine actually mines
            r = mine(sub, backend="distributed", variant="noac",
                     delta=100.0, rho_min=0.5, strategy=strategy)
            # warm re-runs of the exact compiled step (best-of protocol)
            res, t = r.result, r.elapsed_s
            for _ in range(repeat):
                res = r.rerun()
                t = min(t, r.rerun.last_s)
            rows.append([strategy, f"{n:,}", f"{t * 1e3:,.1f}",
                         int(np.asarray(res.keep).sum()),
                         int(res.overflow)])
            raw["noac_distributed"].append(
                {"strategy": strategy, "n": n, "ms": t * 1e3,
                 "kept": int(np.asarray(res.keep).sum())})
    print_table("NOAC-distributed (local mesh, δ=100, ρ=0.5)",
                ["strategy", "|I|", "ms", "#kept", "overflow"], rows)

    # -- incremental vs full streaming snapshots ----------------------------
    n_stream = max(int(full.tuples.shape[0] * 0.5), 256)
    chunk = max(n_stream // 16, 32)
    rows = []
    for mode in ("incremental", "full"):
        sm = StreamingMiner(full.sizes, incremental=(mode == "incremental"))
        snap_times = []
        t_total0 = time.perf_counter()
        for lo in range(0, n_stream, chunk):
            sm.add(full.tuples[lo:lo + chunk])
            t0 = time.perf_counter()
            res = sm.snapshot(full_remine=(mode == "full"))
            np.asarray(res.keep)
            snap_times.append(time.perf_counter() - t0)
        t_total = time.perf_counter() - t_total0
        rows.append([mode, f"{n_stream:,}", len(snap_times),
                     f"{np.mean(snap_times) * 1e3:,.1f}",
                     f"{np.max(snap_times) * 1e3:,.1f}",
                     f"{t_total * 1e3:,.1f}"])
        raw["streaming"].append(
            {"mode": mode, "n": n_stream, "snapshots": len(snap_times),
             "mean_snapshot_ms": float(np.mean(snap_times)) * 1e3,
             "total_ms": t_total * 1e3,
             "stats": dict(sm.stats)})
    print_table("Streaming snapshots — incremental (sorted-run merge) vs "
                "full re-mine",
                ["mode", "|I|", "#snaps", "mean ms", "max ms", "total ms"],
                rows)
    save_json("scaling.json", raw)
    return raw


if __name__ == "__main__":
    run()
