"""Paper Fig. 2/3: runtime-vs-|I| scaling curves.

Fig. 2 analogue: pipeline time as a function of tuple count on the
MovieLens-like stream (expects ~linear — the paper's O(|I|·Σ|A_j|)).
Fig. 3 analogue: NOAC time vs tuple count (two parameterisations,
expecting parameter-independence of runtime, the paper's observation).
"""
from __future__ import annotations

import numpy as np

from repro.core import BatchMiner, NOACMiner
from repro.data import synthetic as S

from .common import print_table, save_json, timeit


def run(scale: float = 0.2, repeat: int = 3):
    raw = {"fig2": [], "fig3": []}
    full = S.movielens_like(n_tuples=int(1_000_000 * scale), seed=0)
    fracs = (0.1, 0.25, 0.5, 0.75, 1.0)
    miner = BatchMiner(full.sizes)
    rows = []
    for f in fracs:
        n = max(int(full.tuples.shape[0] * f), 64)
        t, res = timeit(miner, full.tuples[:n], repeat=repeat)
        n_cl = int(np.asarray(res.is_unique).sum())
        rows.append([f"{n:,}", f"{t * 1e3:,.1f}", f"{n_cl:,}",
                     f"{t / n * 1e6:.2f}"])
        raw["fig2"].append({"n": n, "ms": t * 1e3, "clusters": n_cl})
    print_table("Fig. 2 — pipeline scaling (MovieLens-like)",
                ["|I|", "ms", "#clusters", "µs/tuple"], rows)

    frames = S.semantic_frames_like(n_tuples=int(100_000 * scale), seed=0)
    rows = []
    for delta, rho, minsup in [(100.0, 0.8, 2), (100.0, 0.5, 0)]:
        nm = NOACMiner(frames.sizes, delta=delta, rho_min=rho, minsup=minsup)
        for f in fracs:
            n = max(int(frames.tuples.shape[0] * f), 64)
            vals = frames.values[:n]
            t, res = timeit(nm, frames.tuples[:n], vals, repeat=repeat)
            rows.append([f"NOAC({delta:.0f},{rho},{minsup})", f"{n:,}",
                         f"{t * 1e3:,.1f}",
                         int(np.asarray(res.keep).sum())])
            raw["fig3"].append({"params": [delta, rho, minsup], "n": n,
                                "ms": t * 1e3})
    print_table("Fig. 3 — NOAC scaling (frames-like)",
                ["params", "|I|", "ms", "#kept"], rows)
    save_json("scaling.json", raw)
    return raw


if __name__ == "__main__":
    run()
