"""Ranking layer (serve/ranking.py): scored top-k, the vectorised
batch-query path's bit-identity with the scalar path, packed-signature
resolution, and the ranked-order `cluster_query` regression."""
import numpy as np
import pytest

from repro.core import BatchMiner, StreamingMiner
from repro.data import synthetic
from repro.serve.clusters import ClusterIndex, cluster_query
from repro.serve.ranking import (BatchQuerier, RankingPolicy,
                                 cluster_scores, pack_signatures,
                                 rank_views, top_clusters)


@pytest.fixture(scope="module")
def mined():
    ctx = synthetic.random_context((8, 7, 6), 96, seed=7)
    bm = BatchMiner(ctx.sizes)
    res = bm(ctx.tuples)
    return ctx, ClusterIndex.from_result(res), res


def test_scores_default_policy_is_density(mined):
    _, idx, _ = mined
    scores = cluster_scores(idx)
    assert np.allclose(scores, [c.density for c in idx.clusters])


def test_policy_terms(mined):
    _, idx, _ = mined
    n = len(idx.clusters)
    vol = cluster_scores(idx, RankingPolicy(w_density=0, w_volume=1.0))
    assert vol.max() <= 1.0 + 1e-12 and np.isclose(vol.max(), 1.0)
    # recency: ages=0 scores 1; larger age scores strictly less
    ages = np.arange(n, dtype=np.float64)
    rec = cluster_scores(idx, RankingPolicy(w_density=0, w_recency=1.0),
                         ages=ages)
    assert np.isclose(rec[0], 1.0)
    if n > 1:
        assert np.all(np.diff(rec) < 0)


def test_scalar_batch_bit_identical(mined):
    ctx, idx, _ = mined
    bq = BatchQuerier(idx)
    rng = np.random.default_rng(0)
    for mode in (None, 0, 1, 2):
        size = ctx.sizes[mode or 0]
        # includes out-of-vocabulary entities (no hits) on purpose
        ents = rng.integers(0, size + 3, 40).tolist()
        batch = bq.topk_batch(ents, mode=mode, k=5)
        assert len(batch) == len(ents)
        for e, got in zip(ents, batch):
            want = bq.topk(e, mode=mode, k=5)
            assert [(id(v), s) for v, s in got] \
                == [(id(v), s) for v, s in want]
            # ranked: scores non-increasing
            ss = [s for _, s in got]
            assert ss == sorted(ss, reverse=True)


def test_batch_mode_out_of_range(mined):
    _, idx, _ = mined
    with pytest.raises(ValueError):
        BatchQuerier(idx).topk_batch([0], mode=7)


def test_top_clusters_ranked(mined):
    _, idx, _ = mined
    top = top_clusters(idx, k=5)
    ss = [s for _, s in top]
    assert len(top) == min(5, len(idx)) and ss == sorted(ss, reverse=True)
    assert np.isclose(ss[0], max(c.density for c in idx.clusters))


def test_rank_views_stable_on_ties():
    views = ["a", "b", "c"]
    ranked = rank_views([(views[0], 1.0), (views[1], 2.0),
                         (views[2], 1.0)])
    assert [v for v, _ in ranked] == ["b", "a", "c"]


def test_signature_lookup_batch_cross_engine(mined):
    ctx, idx, _ = mined
    bq = BatchQuerier(idx)
    # streaming-issued signatures resolve against the batch index
    sm = StreamingMiner(ctx.sizes)
    sm.add(ctx.tuples[:48])
    sm.add(ctx.tuples[48:])
    sidx = ClusterIndex.from_result(sm.snapshot())
    sigs = [c.signature for c in sidx.clusters[:8]] + [(0, 0)]
    rows = bq.lookup_signatures(sigs)
    assert rows[-1] == -1
    for sig, row in zip(sigs[:-1], rows[:-1]):
        assert row >= 0 and idx.clusters[row].signature == sig
        assert idx.clusters[row].components \
            == sidx.query(signature=sig)[0].components


def test_pack_signatures_word():
    w = pack_signatures([1, 0xFFFFFFFF], [2, 3])
    assert w.dtype == np.uint64
    assert int(w[0]) == (2 << 32) | 1
    assert int(w[1]) == (3 << 32) | 0xFFFFFFFF


def test_cluster_query_ranked_order_regression(mined):
    """`cluster_query` must return ranked (density-desc) hits, not
    index insertion order."""
    ctx, idx, res = mined
    entity = int(ctx.tuples[0, 0])
    hits = cluster_query(res, entity=entity, mode=0)
    dens = [c.density for c in hits]
    assert dens == sorted(dens, reverse=True)
    assert {c.signature for c in hits} \
        == {c.signature for c in idx.query(entity=entity, mode=0)}
    # global query too
    all_dens = [c.density for c in cluster_query(res)]
    assert all_dens == sorted(all_dens, reverse=True)


def test_serve_exports():
    """Regression: the serving API is reachable from `repro.serve`."""
    import repro.serve as S
    for name in ("TriclusterService", "Snapshot", "QueryResult",
                 "BatchQuerier", "RankingPolicy", "top_clusters",
                 "ClusterClient", "make_server", "ClusterIndex",
                 "cluster_query"):
        assert hasattr(S, name) and name in S.__all__, name
