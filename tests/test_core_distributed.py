"""Distributed (shard_map) engine: 1-device in-process parity + 8-device
subprocess parity (real collectives on a forced host mesh)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import BatchMiner, DistributedMiner, pad_tuples
from repro.data import synthetic
from repro.launch.mesh import make_mesh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("strategy", ["replicate", "shuffle"])
def test_single_device_parity(strategy):
    mesh = make_mesh((1,), ("data",))
    ctx = synthetic.random_context((8, 6, 5), 96, seed=0)
    bm = BatchMiner(ctx.sizes)
    dm = DistributedMiner(ctx.sizes, mesh, axes="data", strategy=strategy)
    want, got = bm(ctx.tuples), dm(ctx.tuples)
    assert int(got.overflow) == 0
    np.testing.assert_array_equal(np.asarray(got.sig_lo),
                                  np.asarray(want.sig_lo))
    np.testing.assert_array_equal(np.asarray(got.gen_count),
                                  np.asarray(want.gen_count))
    np.testing.assert_allclose(np.asarray(got.density),
                               np.asarray(want.density), rtol=1e-6)


def test_multidevice_subprocess():
    """Real 8-device mesh (pod×data too) in a separate process so the main
    test process keeps its single-device view."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_distributed_check.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_padding_is_idempotent():
    ctx = synthetic.random_context((7, 7, 7), 61, seed=1)
    padded = pad_tuples(ctx.tuples, 8)
    assert padded.shape[0] == 64
    bm = BatchMiner(ctx.sizes)
    a, b = bm(ctx.tuples), bm(padded)
    assert int(np.asarray(a.is_unique).sum()) == int(
        np.asarray(b.is_unique).sum())
