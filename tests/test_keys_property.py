"""Hypothesis properties of the packed-key subsystem (``core.keys``):

* packed-key Stage-1/Stage-3 mining is bit-identical to the lexsort
  oracle — every ``PipelineResult`` leaf, including the per-mode sort
  permutations — across random contexts of arity 2–4, with and without
  value columns,
* contexts whose key exceeds 64 bits transparently fall back to the
  lexsort path behind the same API,
* host and device packers produce the same uint64 word bit-for-bit (the
  invariant the streaming engine's merged permutations rest on),
* the order-preserving float32 encoding is a strictly monotone bijection.
"""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import BatchMiner, NOACMiner
from repro.core import keys as K
from repro.core.context import PolyadicContext


@st.composite
def contexts(draw, max_arity=4, max_size=7, max_tuples=40,
             with_values=False):
    arity = draw(st.integers(2, max_arity))
    sizes = tuple(draw(st.integers(2, max_size)) for _ in range(arity))
    n = draw(st.integers(1, max_tuples))
    rows = draw(st.lists(
        st.tuples(*[st.integers(0, s - 1) for s in sizes]),
        min_size=n, max_size=n))
    vals = None
    if with_values:
        # finite, no -0.0/NaN: the documented domain of the
        # order-preserving float encoding (DESIGN.md §3a)
        vals = np.asarray(draw(st.lists(
            st.floats(0.001, 1000.0, width=32), min_size=n, max_size=n)),
            np.float32)
    return PolyadicContext(sizes, np.asarray(rows, np.int32), vals)


def assert_results_identical(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name)


@settings(max_examples=15, deadline=None)
@given(contexts())
def test_packed_prime_bit_identical_to_lexsort(ctx):
    packed = BatchMiner(ctx.sizes, packed=True)
    oracle = BatchMiner(ctx.sizes, packed=False)
    assert packed.packed_active
    assert_results_identical(packed(ctx.tuples), oracle(ctx.tuples))


@settings(max_examples=15, deadline=None)
@given(contexts(with_values=True), st.floats(0.0, 2000.0))
def test_packed_noac_bit_identical_to_lexsort(ctx, delta):
    packed = NOACMiner(ctx.sizes, delta=delta, packed=True)
    oracle = NOACMiner(ctx.sizes, delta=delta, packed=False)
    assert packed.packed_active
    assert_results_identical(packed(ctx.tuples, ctx.values),
                             oracle(ctx.tuples, ctx.values))


def test_over_64_bit_key_falls_back_to_lexsort():
    # 4 modes × 17 bits = 68 key bits: no packed path
    sizes = (1 << 17,) * 4
    rng = np.random.default_rng(0)
    tuples = np.stack([rng.integers(0, s, 64, dtype=np.int32)
                       for s in sizes], 1)
    auto = BatchMiner(sizes)                    # packed=None → auto
    assert not auto.key_plans[0].fits
    assert not auto.packed_active
    assert_results_identical(auto(tuples),
                             BatchMiner(sizes, packed=False)(tuples))
    # value lane pushes a fitting prime key over the edge: 3×11+32 = 65
    nsz = (2048, 2048, 2048)
    assert K.plan_context_keys(nsz, with_values=False)[0].fits
    nm = NOACMiner(nsz, delta=10.0)
    assert not nm.packed_active
    vals = rng.uniform(0, 100, 64).astype(np.float32)
    ntup = np.stack([rng.integers(0, s, 64, dtype=np.int32)
                     for s in nsz], 1)
    assert_results_identical(
        nm(ntup, vals), NOACMiner(nsz, delta=10.0, packed=False)(ntup, vals))


@settings(max_examples=25, deadline=None)
@given(contexts(with_values=True))
def test_host_device_packers_bit_identical(ctx):
    for with_values in (False, True):
        vals = ctx.values if with_values else None
        for plan in K.plan_context_keys(ctx.sizes, with_values=with_values):
            host = plan.pack_host(ctx.tuples, vals)
            words = [np.asarray(w).astype(np.uint64)
                     for w in plan.pack_device(ctx.tuples, vals)]
            dev = (words[0] << np.uint64(32)) | words[1] \
                if plan.words == 2 else words[0]
            np.testing.assert_array_equal(host, dev)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1e30, 1e30, width=32), min_size=2, max_size=50))
def test_float_sort_bits_monotone_bijection(vals):
    v = np.asarray(vals, np.float32)
    v = np.where(v == 0, np.float32(0.0), v)    # normalise -0.0
    enc = K.float_sort_bits_host(v)
    # strictly order-preserving
    order = np.argsort(v, kind="stable")
    assert (np.diff(enc[order].astype(np.int64)) >= 0).all()
    eq = v[:, None] == v[None, :]
    assert (eq == (enc[:, None] == enc[None, :])).all()
    # device encode matches host; decode inverts exactly
    import jax.numpy as jnp
    dev = np.asarray(K.float_sort_bits(jnp.asarray(v)))
    np.testing.assert_array_equal(enc, dev)
    back = np.asarray(K.float_from_sort_bits(jnp.asarray(enc)))
    np.testing.assert_array_equal(back, v)
