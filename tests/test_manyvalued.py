"""NOAC / δ-triclustering vs. the reference oracle (paper §3.2, §4.3)."""
import numpy as np
import pytest

from repro.core import NOACMiner, PolyadicContext
from repro.core import reference as ref
from repro.core.postprocess import cluster_set
from repro.data import synthetic


def _oracle(ctx, delta, rho_min=0.0, minsup=0):
    out = ref.noac(ctx.deduplicated(), delta, rho_min=rho_min, minsup=minsup)
    return {tuple(tuple(sorted(c)) for c in cl) for cl in out}


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("delta", [0.0, 50.0, 200.0, 1e9])
def test_noac_matches_oracle(seed, delta):
    ctx = synthetic.random_context((7, 6, 5), 90, seed=seed, values=True)
    got = cluster_set(NOACMiner(ctx.sizes, delta=delta).mine_context(ctx))
    assert got == _oracle(ctx, delta)


@pytest.mark.parametrize("rho_min,minsup", [(0.0, 2), (0.5, 0), (0.3, 2)])
def test_noac_constraints(rho_min, minsup):
    ctx = synthetic.random_context((6, 6, 6), 80, seed=2, values=True)
    got = cluster_set(NOACMiner(ctx.sizes, delta=100.0, rho_min=rho_min,
                                minsup=minsup).mine_context(ctx))
    assert got == _oracle(ctx, 100.0, rho_min=rho_min, minsup=minsup)


def test_noac_binary_degeneration():
    """W={0,1}, δ=0 must reduce to prime OAC triclusters (paper §3.2)."""
    ctx = synthetic.random_context((6, 5, 4), 60, seed=3)
    got = cluster_set(NOACMiner(ctx.sizes, delta=0.0).mine_context(ctx))
    _, uniq, _, _ = ref.multimodal_clusters(ctx.deduplicated())
    want = {tuple(tuple(sorted(c)) for c in cl) for cl in uniq}
    assert got == want


def test_noac_4ary():
    ctx = synthetic.random_context((5, 4, 4, 3), 70, seed=4, values=True)
    got = cluster_set(NOACMiner(ctx.sizes, delta=75.0).mine_context(ctx))
    assert got == _oracle(ctx, 75.0)


def test_noac_movielens_values():
    ctx = synthetic.movielens_like(400, seed=5).deduplicated()
    got = cluster_set(NOACMiner(ctx.sizes, delta=1.0).mine_context(ctx))
    assert got == _oracle(ctx, 1.0)
