"""ServeEngine: ragged batching correctness and determinism."""
import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.models.api import get_model
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"),
                              dtype="float32")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_ragged_batch_matches_single(setup):
    """A request's greedy output must not depend on its batch neighbours
    (the replay scheme must reproduce single-request decoding)."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=96)
    p_long = list(range(1, 25))
    p_short = [5, 6, 7, 8, 9, 10]
    solo = eng.generate([p_long], max_new_tokens=8).tokens[0]
    both = eng.generate([p_long, p_short], max_new_tokens=8).tokens
    assert both[0] == solo
    assert len(both[1]) == 8


def test_greedy_deterministic(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64)
    prompts = [[1, 2, 3, 4], [9, 8, 7]]
    a = eng.generate(prompts, max_new_tokens=6).tokens
    b = eng.generate(prompts, max_new_tokens=6).tokens
    assert a == b


def test_eos_stops_sequence(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64)
    probe = eng.generate([[1, 2, 3, 4]], max_new_tokens=4).tokens[0]
    eos = probe[1]
    want = probe[:probe.index(eos) + 1]   # up to the first eos occurrence
    eng_eos = ServeEngine(cfg, params, max_len=64, eos_id=eos)
    out = eng_eos.generate([[1, 2, 3, 4]], max_new_tokens=8).tokens[0]
    assert out == want            # stopped at the eos token
