"""Streaming/online engine: snapshot exactness, upsert/delete streams
and checkpoint/restore (runs restored, not rebuilt)."""
import numpy as np

from repro.core import BatchMiner, NOACMiner, StreamingMiner
from repro.core.postprocess import cluster_set
from repro.core.streaming import StreamState
from repro.data import synthetic


def test_snapshots_match_batch_at_every_chunk():
    ctx = synthetic.random_context((8, 7, 6), 96, seed=0)
    sm = StreamingMiner(ctx.sizes)
    bm = BatchMiner(ctx.sizes)
    for start in range(0, 96, 32):
        sm.add(ctx.tuples[start:start + 32])
        seen = ctx.tuples[:start + 32]
        want = cluster_set(bm.mine_context(
            type(ctx)(ctx.sizes, seen)))
        got = cluster_set(sm.snapshot_clusters())
        assert got == want


def test_checkpoint_restore_resumes_stream():
    ctx = synthetic.random_context((6, 6, 6), 64, seed=1)
    sm = StreamingMiner(ctx.sizes)
    sm.add(ctx.tuples[:32])
    blob = sm.state.checkpoint()
    # restart: the run arrays come back from the blob — only the rows
    # ingested after the restore are chunk-sorted (O(T) array loads,
    # not an O(T log T) rebuild)
    sm2 = StreamingMiner(ctx.sizes)
    sm2.state = StreamState.restore(blob)
    sm2.add(ctx.tuples[32:])
    assert sm2.stats["chunk_sorted_rows"] == 32
    bm = BatchMiner(ctx.sizes)
    assert (cluster_set(sm2.snapshot_clusters())
            == cluster_set(bm.mine_context(ctx)))


def test_legacy_buffer_blob_still_restores():
    """Old (pre-run-checkpoint) blobs carry only the buffer: restore
    takes the lazy path — one full chunk sort on resume — and mines
    identically."""
    ctx = synthetic.random_context((6, 6, 6), 64, seed=2)
    sm = StreamingMiner(ctx.sizes)
    sm.add(ctx.tuples[:32])
    blob = {"buffer": ctx.tuples[:32].copy(), "count": 32}
    sm2 = StreamingMiner(ctx.sizes)
    sm2.state = StreamState.restore(blob)
    sm2.add(ctx.tuples[32:])
    assert sm2.stats["chunk_sorted_rows"] == 64     # full lazy rebuild
    bm = BatchMiner(ctx.sizes)
    assert (cluster_set(sm2.snapshot_clusters())
            == cluster_set(bm.mine_context(ctx)))


def test_upsert_delete_stream_matches_batch_survivors():
    """Tombstone streaming (NOAC): upserts replace a row's value (last
    write wins), deletes drop every version — snapshots equal batch
    mining of the canonicalised survivor set, and the incremental path
    stays bit-identical to the full device re-sort."""
    ctx = synthetic.random_context((7, 6, 5), 80, seed=3,
                                   values=True).deduplicated()
    delta = 60.0
    sm = StreamingMiner(ctx.sizes, delta=delta)
    sm.add(ctx.tuples, ctx.values)
    # conflicting re-arrival: add IS upsert on valued streams
    sm.add(ctx.tuples[:7], ctx.values[:7] + 25.0)
    sm.upsert(ctx.tuples[7:12], ctx.values[7:12] - 5.0)
    sm.delete(ctx.tuples[12:20])
    surv_rows = np.concatenate([ctx.tuples[:12], ctx.tuples[20:]])
    surv_vals = np.concatenate([ctx.values[:7] + 25.0,
                                ctx.values[7:12] - 5.0, ctx.values[20:]])
    inc = sm.snapshot()
    full = sm.snapshot(full_remine=True)
    np.testing.assert_array_equal(np.asarray(inc.sig_lo),
                                  np.asarray(full.sig_lo))
    nm = NOACMiner(ctx.sizes, delta=delta)
    assert (cluster_set(sm.materialise(inc))
            == cluster_set(nm.materialise(nm(surv_rows, surv_vals))))
    assert sm.stats["tombstoned_rows"] == 12 + 8
    assert sm.state.dead == 0               # snapshots compact them away
