"""Streaming/online engine: snapshot exactness + checkpoint/restore."""
import numpy as np

from repro.core import BatchMiner, StreamingMiner
from repro.core.postprocess import cluster_set
from repro.core.streaming import StreamState
from repro.data import synthetic


def test_snapshots_match_batch_at_every_chunk():
    ctx = synthetic.random_context((8, 7, 6), 96, seed=0)
    sm = StreamingMiner(ctx.sizes)
    bm = BatchMiner(ctx.sizes)
    for start in range(0, 96, 32):
        sm.add(ctx.tuples[start:start + 32])
        seen = ctx.tuples[:start + 32]
        want = cluster_set(bm.mine_context(
            type(ctx)(ctx.sizes, seen)))
        got = cluster_set(sm.snapshot_clusters())
        assert got == want


def test_checkpoint_restore_resumes_stream():
    ctx = synthetic.random_context((6, 6, 6), 64, seed=1)
    sm = StreamingMiner(ctx.sizes)
    sm.add(ctx.tuples[:32])
    blob = sm.state.checkpoint()
    # restart
    sm2 = StreamingMiner(ctx.sizes)
    sm2.state = StreamState.restore(blob)
    sm2.add(ctx.tuples[32:])
    bm = BatchMiner(ctx.sizes)
    assert (cluster_set(sm2.snapshot_clusters())
            == cluster_set(bm.mine_context(ctx)))
