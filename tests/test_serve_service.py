"""TriclusterService (serve/service.py): snapshot-swap atomicity under
concurrent readers/writers, freshness modes, versioning hooks, and
cross-engine signature resolution through the served path."""
import threading
import time

import numpy as np
import pytest

from repro.core import BatchMiner
from repro.data import synthetic
from repro.serve.clusters import ClusterIndex
from repro.serve.service import TriclusterService


@pytest.fixture(scope="module")
def ctx():
    return synthetic.random_context((8, 7, 6), 96, seed=7)


def _service(ctx, **kw):
    svc = TriclusterService(ctx.sizes, refresh_interval=0.01,
                            dirty_threshold=1, **kw)
    svc.add(ctx.tuples)
    return svc


def test_lifecycle_and_freshness(ctx):
    svc = _service(ctx)
    with svc:
        snap = svc.snapshot()
        assert snap.version == 1 and len(snap.index) > 0
        assert snap.stream_version == svc.miner.stream_version
        # explicit refresh always advances, even when clean
        snap2 = svc.refresh()
        assert snap2.version == 2
        # at_least_version on an already-published version is immediate
        assert svc.snapshot(at_least_version=2, timeout=1).version >= 2
        # unreachable version times out
        with pytest.raises(TimeoutError):
            svc.snapshot(at_least_version=99, timeout=0.05)
        # background remine picks up a write on its own
        svc.delete(ctx.tuples[:3])
        got = svc.snapshot(at_least_version=3, timeout=30)
        assert got.stream_version >= 2       # covers the delete


def test_versioning_hooks(ctx):
    svc = _service(ctx)
    m = svc.miner
    v0 = m.stream_version
    svc.upsert(ctx.tuples[:2])
    svc.delete(ctx.tuples[2:3])
    assert m.stream_version == v0 + 2
    svc.refresh()
    assert m.snapshot_stream_version == m.stream_version
    assert svc.snapshot().stream_version == m.stream_version


def test_query_matches_direct_index(ctx):
    """A served query is bit-identical to a direct ClusterIndex query
    on the same snapshot."""
    svc = _service(ctx)
    with svc:
        snap = svc.snapshot()
        direct = ClusterIndex.from_result(snap.result)
        entity = int(ctx.tuples[0, 1])
        served = svc.query(entity=entity, mode=1, k=10_000).hits
        assert {v.signature for v, _ in served} \
            == {c.signature for c in direct.query(entity=entity, mode=1)}
        # signature round-trip: served == snap.index.query == direct
        sig = direct.clusters[0].signature
        hit = svc.query(signature=sig).hits
        assert hit and hit[0][0] is snap.index.query(signature=sig)[0]
        assert hit[0][0].components \
            == direct.query(signature=sig)[0].components


def test_concurrent_readers_only_see_complete_snapshots(ctx):
    """Readers under a live writer: versions never regress, and every
    observed snapshot is internally complete — its index holds exactly
    its own result's kept clusters, and a signature drawn from the
    snapshot resolves against the same snapshot's index bit-identically.
    A torn swap would fail one of these."""
    svc = _service(ctx)
    errors: list = []
    stop = threading.Event()

    def writer():
        rng = np.random.default_rng(1)
        while not stop.is_set():
            sel = rng.integers(0, ctx.tuples.shape[0], 3)
            svc.upsert(ctx.tuples[sel])
            if rng.random() < 0.3:
                svc.delete(ctx.tuples[rng.integers(0, 96, 1)])
            time.sleep(0.002)

    def reader():
        last = 0
        try:
            for _ in range(300):
                snap = svc.snapshot()
                if snap.version < last:
                    errors.append(f"version regressed {last}->"
                                  f"{snap.version}")
                last = snap.version
                kept = int(np.asarray(snap.result.keep).sum())
                if len(snap.index) != kept:
                    errors.append(f"torn snapshot v{snap.version}: "
                                  f"index {len(snap.index)} != kept {kept}")
                if len(snap.index):
                    c = snap.index.clusters[0]
                    got = snap.index.query(signature=c.signature)
                    if not got or got[0] is not c:
                        errors.append("signature did not resolve within "
                                      "its own snapshot")
                    res = svc.query(signature=c.signature)
                    # the service may have swapped since; only compare
                    # when it answered from the same version
                    if res.version == snap.version and (
                            not res.hits or res.hits[0][0] is not c):
                        errors.append("served signature query != direct "
                                      "index query on same snapshot")
        except Exception as e:          # noqa: BLE001 — fail the test
            errors.append(repr(e))

    with svc:
        w = threading.Thread(target=writer, daemon=True)
        readers = [threading.Thread(target=reader, daemon=True)
                   for _ in range(2)]
        w.start()
        for r in readers:
            r.start()
        for r in readers:
            r.join(timeout=60)
        stop.set()
        w.join(timeout=10)
    assert not errors, errors[:5]
    assert svc.stats()["publishes"] >= 2, "no snapshot swap ever happened"


def test_cross_engine_signature_resolution(ctx):
    """Batch-issued signatures resolve through the (streaming-backed)
    service, and the final served state equals a batch re-mine of the
    survivor set."""
    svc = _service(ctx)
    with svc:
        dead = {tuple(r) for r in ctx.tuples[:7].tolist()}
        svc.delete(ctx.tuples[:7])
        snap = svc.refresh()
        survivors = np.asarray(
            [r for r in ctx.tuples.tolist() if tuple(r) not in dead],
            np.int32)
        bidx = ClusterIndex.from_result(BatchMiner(ctx.sizes)(survivors))
        assert {c.signature for c in bidx.clusters} \
            == {c.signature for c in snap.index.clusters}
        for c in bidx.clusters[:5]:
            hit = svc.query(signature=c.signature,
                            at_least_version=snap.version).hits
            assert hit and hit[0][0].components == c.components


def test_distributed_backend(ctx):
    svc = TriclusterService(ctx.sizes, backend="distributed",
                            refresh_interval=0.01, dirty_threshold=1)
    svc.add(ctx.tuples[:48])
    svc.add(ctx.tuples[48:])
    with svc:
        snap = svc.snapshot()
        ref = _service(ctx)
        rsnap = ref.refresh()
        assert {c.signature for c in snap.index.clusters} \
            == {c.signature for c in rsnap.index.clusters}
        svc.upsert(ctx.tuples[:2])
        assert svc.refresh().version == snap.version + 1


def test_no_snapshot_before_start(ctx):
    svc = TriclusterService(ctx.sizes)
    with pytest.raises(RuntimeError):
        svc.snapshot()
    with pytest.raises(ValueError):
        svc.refresh()               # no data ingested yet
