"""Subprocess body: run the distributed miner on an 8-device host mesh and
compare against the single-device batch engine. Invoked by
test_core_distributed.py; prints 'OK' on success."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax

from repro.core import BatchMiner, DistributedMiner, pad_tuples
from repro.data import synthetic


def check(mesh, axes, strategy, sizes, t, theta, seed):
    ctx = synthetic.random_context(sizes, t, seed=seed)
    n_sh = int(np.prod([mesh.shape[a] for a in
                        ((axes,) if isinstance(axes, str) else axes)]))
    tuples = pad_tuples(ctx.tuples, n_sh)
    bm = BatchMiner(sizes, theta=theta)
    want = bm(tuples)
    dm = DistributedMiner(sizes, mesh, axes=axes, theta=theta,
                          strategy=strategy)
    got = dm(tuples)
    assert int(got.overflow) == 0, f"overflow={int(got.overflow)}"
    for name in ["sig_lo", "sig_hi", "gen_count", "volume", "density"]:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_allclose(a, b, rtol=1e-6, err_msg=name)
    # unique flags may pick different representatives per cluster; compare
    # the *set* of (sig, density) of unique clusters instead.
    def uniq_set(r):
        u = np.asarray(r.is_unique)
        return set(zip(np.asarray(r.sig_lo)[u].tolist(),
                       np.asarray(r.sig_hi)[u].tolist()))
    assert uniq_set(got) == uniq_set(want)
    assert int(got.n_clusters) == int(np.asarray(want.is_unique).sum())
    # keep counts agree
    assert (np.asarray(got.keep).sum() == np.asarray(want.keep).sum())


def main():
    auto = (jax.sharding.AxisType.Auto,)
    mesh8 = jax.make_mesh((8,), ("data",), axis_types=auto)
    mesh2x4 = jax.make_mesh((2, 4), ("pod", "data"), axis_types=auto * 2)
    for strategy in ("replicate", "shuffle"):
        check(mesh8, "data", strategy, (9, 7, 5), 160, 0.0, seed=0)
        check(mesh8, "data", strategy, (6, 6, 6, 4), 240, 0.3, seed=1)
        check(mesh2x4, ("pod", "data"), strategy, (9, 7, 5), 160, 0.0, seed=2)
    print("OK")


if __name__ == "__main__":
    main()
