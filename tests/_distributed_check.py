"""Subprocess body: run the distributed miner on an 8-device host mesh and
compare against the single-device batch/NOAC engines — prime and NOAC
variants, both merge strategies, bit-identical signatures. Invoked by
test_core_distributed.py; prints 'OK' on success."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax

from repro.core import (BatchMiner, DistributedMiner, NOACMiner, pad_tuples,
                        pad_values)
from repro.data import synthetic
from repro.launch.mesh import make_mesh


def _compare(got, want):
    assert int(got.overflow) == 0, f"overflow={int(got.overflow)}"
    for name in ["sig_lo", "sig_hi", "gen_count", "volume", "density"]:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_allclose(a, b, rtol=1e-6, err_msg=name)
    # unique flags may pick different representatives per cluster; compare
    # the *set* of signatures of unique clusters instead.
    def uniq_set(r):
        u = np.asarray(r.is_unique)
        return set(zip(np.asarray(r.sig_lo)[u].tolist(),
                       np.asarray(r.sig_hi)[u].tolist()))
    assert uniq_set(got) == uniq_set(want)
    assert int(got.n_clusters) == int(np.asarray(want.is_unique).sum())
    assert (np.asarray(got.keep).sum() == np.asarray(want.keep).sum())


def check(mesh, axes, strategy, sizes, t, theta, seed):
    ctx = synthetic.random_context(sizes, t, seed=seed)
    n_sh = int(np.prod([mesh.shape[a] for a in
                        ((axes,) if isinstance(axes, str) else axes)]))
    tuples = pad_tuples(ctx.tuples, n_sh)
    bm = BatchMiner(sizes, theta=theta)
    want = bm(tuples)
    dm = DistributedMiner(sizes, mesh, axes=axes, theta=theta,
                          strategy=strategy)
    _compare(dm(tuples), want)


def check_noac(mesh, axes, strategy, sizes, t, delta, rho_min, minsup, seed):
    ctx = synthetic.random_context(sizes, t, seed=seed,
                                   values=True).deduplicated()
    n_sh = int(np.prod([mesh.shape[a] for a in
                        ((axes,) if isinstance(axes, str) else axes)]))
    tuples = pad_tuples(ctx.tuples, n_sh)
    values = pad_values(ctx.values, n_sh)
    nm = NOACMiner(sizes, delta=delta, rho_min=rho_min, minsup=minsup)
    want = nm(tuples, values)
    dm = DistributedMiner(sizes, mesh, axes=axes, strategy=strategy,
                          delta=delta, rho_min=rho_min, minsup=minsup)
    _compare(dm(tuples, values), want)


def main():
    mesh8 = make_mesh((8,), ("data",))
    mesh2x4 = make_mesh((2, 4), ("pod", "data"))
    for strategy in ("replicate", "shuffle"):
        check(mesh8, "data", strategy, (9, 7, 5), 160, 0.0, seed=0)
        check(mesh8, "data", strategy, (6, 6, 6, 4), 240, 0.3, seed=1)
        check(mesh2x4, ("pod", "data"), strategy, (9, 7, 5), 160, 0.0, seed=2)
        check_noac(mesh8, "data", strategy, (9, 7, 5), 160, 120.0, 0.0, 0,
                   seed=3)
        check_noac(mesh2x4, ("pod", "data"), strategy, (7, 6, 5), 120, 80.0,
                   0.3, 2, seed=4)
    print("OK")


if __name__ == "__main__":
    main()
