"""Hypothesis property tests on the system's invariants (brief
deliverable c). The core M/R-algebra properties the paper relies on:

* idempotence under tuple duplication (at-least-once delivery, §5.1 K3),
* invariance under tuple permutation (shard order never matters),
* Alg.-7 density bounds and exact cluster-count semantics vs the oracle,
* deterministic, step-indexed data pipeline (resume correctness).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import BatchMiner
from repro.core.reference import multimodal_clusters
from repro.core.context import PolyadicContext
from repro.data.tokens import TokenPipeline
from repro.configs import get_smoke_config


@st.composite
def contexts(draw, max_arity=4, max_size=7, max_tuples=40):
    arity = draw(st.integers(2, max_arity))
    sizes = tuple(draw(st.integers(2, max_size)) for _ in range(arity))
    n = draw(st.integers(1, max_tuples))
    rows = draw(st.lists(
        st.tuples(*[st.integers(0, s - 1) for s in sizes]),
        min_size=n, max_size=n))
    return PolyadicContext(sizes, np.asarray(rows, np.int32))


@settings(max_examples=25, deadline=None)
@given(contexts(), st.randoms(use_true_random=False))
def test_duplication_and_permutation_invariance(ctx, rnd):
    """mine(I) == mine(shuffle(I + duplicates)) on cluster signatures —
    the paper's M/R at-least-once argument (§5.1) as an algebra law."""
    miner = BatchMiner(ctx.sizes)
    base = miner(ctx.tuples)

    idx = list(range(ctx.num_tuples)) + [
        rnd.randrange(ctx.num_tuples) for _ in range(ctx.num_tuples // 2)]
    rnd.shuffle(idx)
    noisy = miner(ctx.tuples[np.asarray(idx)])

    def cluster_set(res):
        u = np.asarray(res.is_unique)
        return set(zip(np.asarray(res.sig_lo)[u].tolist(),
                       np.asarray(res.sig_hi)[u].tolist(),
                       np.asarray(res.gen_count)[u].tolist(),
                       np.asarray(res.volume)[u].tolist()))

    assert cluster_set(base) == cluster_set(noisy)


@settings(max_examples=25, deadline=None)
@given(contexts())
def test_matches_oracle_and_density_bounds(ctx):
    miner = BatchMiner(ctx.sizes)
    res = miner(ctx.tuples)
    _, unique, density, _ = multimodal_clusters(ctx)
    assert int(np.asarray(res.is_unique).sum()) == len(unique)
    d = np.asarray(res.density)
    vol = np.asarray(res.volume)
    gen = np.asarray(res.gen_count)
    assert (d > 0).all() and (d <= 1.0 + 1e-6).all()
    np.testing.assert_allclose(d, gen / np.maximum(vol, 1.0), rtol=1e-6)
    # every generating tuple's cluster contains the tuple itself =>
    # gen_count >= 1 and volume >= 1
    assert (gen >= 1).all() and (vol >= 1).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(8, 64))
def test_token_pipeline_deterministic_and_stateless(seed, batch, seq):
    """batch_at(step) is a pure function — crash/restart reproducibility."""
    cfg = get_smoke_config("qwen3-0.6b")
    a = TokenPipeline(cfg, batch, seq, seed=seed)
    b = TokenPipeline(cfg, batch, seq, seed=seed)
    for step in (0, 3, 7):
        xa, xb = a.batch_at(step), b.batch_at(step)
        np.testing.assert_array_equal(xa["tokens"], xb["tokens"])
        np.testing.assert_array_equal(xa["labels"], xb["labels"])
    # labels are next-token shifted with a -100 tail
    x = a.batch_at(1)
    np.testing.assert_array_equal(x["labels"][:, :-1], x["tokens"][:, 1:])
    assert (x["labels"][:, -1] == -100).all()
    assert x["tokens"].min() >= 0
    assert x["tokens"].max() < cfg.vocab_size


@settings(max_examples=15, deadline=None)
@given(contexts(max_arity=3, max_size=6, max_tuples=24),
       st.floats(0.05, 1.0))
def test_theta_filter_monotone(ctx, theta):
    """Raising θ never yields more kept clusters; θ=0 keeps all unique."""
    m0 = BatchMiner(ctx.sizes, theta=0.0)
    mt = BatchMiner(ctx.sizes, theta=theta)
    r0, rt = m0(ctx.tuples), mt(ctx.tuples)
    k0 = int(np.asarray(r0.keep).sum())
    kt = int(np.asarray(rt.keep).sum())
    assert kt <= k0
    assert k0 == int(np.asarray(r0.is_unique).sum())
