"""Per-architecture smoke tests (brief deliverable f): every assigned
arch instantiates a reduced config of the same family and runs one
forward/train step on CPU — output shapes + finiteness asserted. A
subset additionally checks prefill+decode against the full forward
(cache correctness)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_mesh
from repro.models.api import get_model, input_specs
from repro.sharding.rules import MeshRules
from repro.train.step import TrainConfig, init_train_state, jit_train_step

B, S = 2, 16


def _batch(cfg):
    return {k: jnp.asarray(v)
            for k, v in TokenPipeline(cfg, B, S, seed=0).batch_at(0).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh)
    batch = _batch(cfg)
    with mesh:
        model = get_model(cfg)
        params = model.init(cfg, jax.random.PRNGKey(0))
        logits, aux = model.forward(cfg, params, batch, rules)
        s_out = S + (cfg.frontend_len if cfg.frontend == "patch" else 0)
        assert logits.shape == (B, s_out, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), arch
        # one optimizer step
        state = init_train_state(cfg, jax.random.PRNGKey(1))
        step = jit_train_step(cfg, rules, TrainConfig(total_steps=10,
                                                      warmup_steps=1))
        state2, metrics = step(state, batch)
        state2, metrics = step(state2, batch)   # step 0 has lr=0 (warmup)
        assert np.isfinite(float(metrics["loss"])), arch
        assert np.isfinite(float(metrics["grad_norm"])), arch
        # params actually changed
        delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
            jax.tree.leaves(state2["params"]),
            jax.tree.leaves(init_train_state(
                cfg, jax.random.PRNGKey(1))["params"])))
        assert delta > 0, arch


DECODE_ARCHS = ["qwen3-0.6b", "mixtral-8x7b", "zamba2-7b", "xlstm-125m",
                "internvl2-76b"]


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.xfail(
        reason="KNOWN DEFECT (open): prefill-path logits diverge from the "
               "parallel forward for the hybrid family (~7e-2 max abs); "
               "decode caches under investigation — see EXPERIMENTS.md "
               "§7; reproduces only on some jax versions, so non-strict",
        strict=False) if a == "zamba2-7b" else ())
    for a in DECODE_ARCHS])
def test_prefill_decode_matches_forward(arch):
    """The decode path (ring cache / SSM states / LSTM states) must agree
    with the full parallel forward.

    Comparisons are same-length: capacity-based MoE drops depend on the
    sequence length (cap = ceil(s·k/E·c)), so forward(S+1) is *expected*
    to differ from prefill(S) at earlier positions for MoE — and the
    s==1 decode path intentionally uses the dense all-expert combine
    (no drops), so the MoE decode check uses a loose tolerance."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              logits_fp32=True)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg, B, S + 1, seed=0)
    full = pipe.batch_at(0)
    toks = jnp.asarray(full["tokens"])
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :S]}
    inputs = {"tokens": toks[:, :S]}
    if cfg.frontend == "patch":
        patches = jnp.asarray(full["patches"])
        batch_full["patches"] = batch_pre["patches"] = patches
        inputs["patches"] = patches
    # prefill(S) == same-length forward(S) at the last position
    logits_same, _ = model.forward(cfg, params, batch_pre)
    cache, logits_pre = model.prefill(cfg, params, inputs, S + 8)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_same[:, -1]),
                               rtol=2e-3, atol=2e-3)
    # decode_step(token S+1) vs forward(S+1) at the last position
    logits_all, _ = model.forward(cfg, params, batch_full)
    cache, logits_dec = model.decode_step(cfg, params, cache, toks[:, -1])
    if cfg.is_moe:
        # dense-combine decode vs capacity forward: agreement up to drops
        corr = np.corrcoef(np.asarray(logits_dec).ravel(),
                           np.asarray(logits_all[:, -1]).ravel())[0, 1]
        assert corr > 0.99, corr
    else:
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_all[:, -1]),
                                   rtol=2e-3, atol=2e-3)


def test_encdec_smoke_decode():
    cfg = dataclasses.replace(get_smoke_config("seamless-m4t-large-v2"),
                              dtype="float32")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg, B, S, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    logits, _ = model.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    cache, lg = model.prefill(cfg, params,
                              {"frames": batch["frames"],
                               "tokens": batch["tokens"]}, S + 4)
    assert lg.shape == (B, cfg.vocab_size)
    cache, lg2 = model.decode_step(cfg, params, cache,
                                   jnp.zeros((B,), jnp.int32))
    assert lg2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg2).all())


def test_input_specs_cover_all_cells():
    """Every (arch × shape) cell yields well-formed ShapeDtypeStructs."""
    from repro.configs import SHAPES, cells, get_config
    n_run = n_skip = 0
    for arch, shape_name, runs, why in cells():
        cfg = get_config(arch)
        if not runs:
            n_skip += 1
            assert why
            continue
        n_run += 1
        specs = input_specs(cfg, SHAPES[shape_name])
        assert "tokens" in specs or cfg.family == "encdec"
        for s in jax.tree.leaves(specs):
            assert isinstance(s, jax.ShapeDtypeStruct)
    assert n_run + n_skip == 40
    assert n_skip == 6
