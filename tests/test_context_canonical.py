"""Valued-context canonicalisation (core/context.py): V must be a
function of the tuple (paper §3.2), so duplicate rows of a many-valued
context collapse at construction with the *last* value winning — the
upsert semantics of the online algorithm.

Regression for the historical ``benchmarks/table5.py`` NOAC(100,0.5,0)
seq-vs-par MISMATCH: the frames-like dataset carries duplicate triples
with conflicting frequencies, and before canonicalisation the
sequential reference and the vectorised engine resolved the conflict
differently.
"""
import dataclasses

import numpy as np

from repro.core import NOACMiner
from repro.core import reference as R
from repro.core.context import PolyadicContext
from repro.data import synthetic


def test_valued_duplicates_keep_last():
    rows = np.array([[0, 1, 2], [1, 0, 0], [0, 1, 2], [0, 1, 2]], np.int32)
    vals = np.array([1.0, 5.0, 2.0, 3.0], np.float32)
    ctx = PolyadicContext((2, 2, 3), rows, vals)
    assert ctx.num_tuples == 2
    got = {tuple(r): v for r, v in zip(ctx.tuples.tolist(),
                                       ctx.values.tolist())}
    assert got == {(0, 1, 2): 3.0, (1, 0, 0): 5.0}


def test_unvalued_duplicates_stay_legal():
    rows = np.array([[0, 1], [0, 1], [1, 0]], np.int32)
    ctx = PolyadicContext((2, 2), rows)
    assert ctx.num_tuples == 3          # M/R at-least-once: dups legal


def test_consistent_duplicates_also_collapse():
    rows = np.array([[0, 0], [0, 0]], np.int32)
    ctx = PolyadicContext((1, 1), rows, np.array([7.0, 7.0], np.float32))
    assert ctx.num_tuples == 1
    assert float(ctx.values[0]) == 7.0


def test_empty_valued_context_ok():
    ctx = PolyadicContext((2, 2), np.zeros((0, 2), np.int32),
                          np.zeros((0,), np.float32))
    assert ctx.num_tuples == 0


def test_table5_noac_seq_vs_par_parity():
    """The exact table5 configuration that used to MISMATCH:
    NOAC(100, 0.5, 0) on a frames-like slice with conflicting-value
    duplicate triples."""
    full = synthetic.semantic_frames_like(n_tuples=800, seed=0)
    # construction already canonicalised; re-introduce the benchmark's
    # slicing pattern to mirror table5.run exactly
    sub = dataclasses.replace(full, tuples=full.tuples[:400],
                              values=full.values[:400])
    seq = R.noac(sub, 100.0, rho_min=0.5, minsup=0)
    miner = NOACMiner(full.sizes, delta=100.0, rho_min=0.5, minsup=0)
    par = int(np.asarray(miner(sub.tuples, sub.values).keep).sum())
    assert len(seq) == par
