"""Numerical parity of the optimised model paths vs their baselines
(the §Perf iterations must not change the math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import common as C
from repro.models import xlstm as X
from repro.models.api import get_model
from repro.sharding.rules import MeshRules


def test_mlstm_chunked_matches_monolithic():
    """X1: the chunkwise-parallel mLSTM equals the S×S form."""
    cfg = get_smoke_config("xlstm-125m")
    cfg_chunked = dataclasses.replace(cfg, ssm_chunk=8)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda a: a[0][0], params["layers"]["mlstm_main"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y1, c1, n1, m1 = X.mlstm_forward(cfg, p0, x, return_state=True)
    y2, c2, n2, m2 = X.mlstm_forward(cfg_chunked, p0, x, return_state=True)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(c1, c2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(n1, n2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(m1, m2, rtol=2e-4, atol=2e-4)


def test_moe_shard_map_matches_gspmd():
    """M1: per-shard dispatch + psum equals the partitioner path."""
    cfg = get_smoke_config("mixtral-8x7b")
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(2))
    pl = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, cfg.d_model))
    cfg_g = dataclasses.replace(cfg, moe_impl="gspmd")
    with mesh:
        y_g, aux_g = jax.jit(lambda p, xx: C.moe_ffn(cfg_g, p, xx, rules)
                             )(pl, x)
        y_s, aux_s = jax.jit(lambda p, xx: C.moe_ffn(cfg, p, xx, rules)
                             )(pl, x)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_s), rtol=1e-5)


@pytest.mark.parametrize("window", [None, 8])
def test_blocked_attention_matches_einsum(window):
    """P2: lax.scan q-blocking equals the monolithic mask path."""
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"),
                              window=window, q_block=8)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    pos = jnp.arange(32, dtype=jnp.int32)
    y_e = C.attention(cfg, p0["attn"], x, pos, impl="einsum")
    y_b = C.attention(cfg, p0["attn"], x, pos, impl="blocked", q_block=8)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_b),
                               rtol=2e-4, atol=2e-4)


def test_decode_gqa_no_repeat_matches_reference():
    """D1: grouped-query decode equals an explicit repeat-to-H reference."""
    cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"),
                              window=None, dtype="float32")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda a: a[0], params["layers"])["attn"]
    b, sc = 2, 16
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (b, 1, cfg.d_model))
    kc = jax.random.normal(jax.random.PRNGKey(2), (b, sc, kv, hd))
    vc = jax.random.normal(jax.random.PRNGKey(3), (b, sc, kv, hd))
    slot_pos = jnp.arange(sc, dtype=jnp.int32)
    pos = jnp.asarray(sc - 1, jnp.int32)
    out, kc2, vc2, sp2 = C.attention_decode(cfg, p0, x, kc, vc, slot_pos,
                                            pos)
    # reference: repeat kv to H and run dense softmax attention
    q, k, v = C._qkv(cfg, p0, x, pos[None])
    kc_ref = jax.lax.dynamic_update_slice_in_dim(
        kc, k.astype(kc.dtype), pos % sc, axis=1)
    vc_ref = jax.lax.dynamic_update_slice_in_dim(
        vc, v.astype(vc.dtype), pos % sc, axis=1)
    g = cfg.n_heads // kv
    kk = jnp.repeat(kc_ref, g, axis=2)
    vv = jnp.repeat(vc_ref, g, axis=2)
    s = jnp.einsum("bqhk,bthk->bhqt", q, kk) * cfg.head_dim ** -0.5
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthk->bqhk", a, vv).reshape(b, 1, -1)
    ref = jnp.einsum("bse,ed->bsd", o,
                     p0["wo"].reshape(-1, cfg.d_model))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(kc2), np.asarray(kc_ref))
