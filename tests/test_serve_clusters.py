"""Cluster-query serving surface (serve/clusters.py): entity → clusters
and signature → cluster lookups over the unified ``PipelineResult``,
cross-checked against the materialiser."""
import numpy as np
import pytest

from repro.core import BatchMiner, StreamingMiner
from repro.core.postprocess import cluster_set
from repro.data import synthetic
from repro.serve.clusters import ClusterIndex, cluster_query


@pytest.fixture(scope="module")
def mined():
    ctx = synthetic.random_context((8, 7, 6), 96, seed=7)
    bm = BatchMiner(ctx.sizes)
    res = bm(ctx.tuples)
    return ctx, bm, res


def test_index_matches_materialise(mined):
    ctx, bm, res = mined
    idx = ClusterIndex.from_result(res)
    want = cluster_set(bm.materialise(res))
    got = {tuple(tuple(sorted(c)) for c in cv.components) for cv in idx}
    assert got == want and len(idx) == len(want)


def test_entity_query_modes(mined):
    ctx, bm, res = mined
    idx = ClusterIndex.from_result(res)
    entity = int(ctx.tuples[0, 1])
    hits = idx.query(entity=entity, mode=1)
    assert hits and all(entity in c.components[1] for c in hits)
    # exactly the clusters whose mode-1 component holds the entity
    assert (sorted(c.signature for c in hits)
            == sorted(c.signature for c in idx
                      if entity in c.components[1]))
    # any-mode query is a superset of every per-mode query
    any_hits = {c.signature for c in idx.query(entity=entity)}
    for k in range(3):
        assert {c.signature
                for c in idx.query(entity=entity, mode=k)} <= any_hits
    with pytest.raises(ValueError):
        idx.query(entity=entity, mode=5)


def test_signature_query_and_density_filter(mined):
    ctx, bm, res = mined
    idx = ClusterIndex.from_result(res)
    some = idx.clusters[0]
    assert idx.query(signature=some.signature) == [some]
    assert idx.query(signature=(0, 0)) == []
    dense = idx.query(min_density=0.5)
    assert all(c.density >= 0.5 for c in dense)
    # one-shot wrapper agrees with the prebuilt index
    assert (cluster_query(res, signature=some.signature)[0].components
            == some.components)


def test_signature_resolves_across_engines(mined):
    """A signature handed out by the batch engine resolves against a
    streaming snapshot's index (same seed ⇒ bit-identical signatures)."""
    ctx, bm, res = mined
    sm = StreamingMiner(ctx.sizes)
    sm.add(ctx.tuples[:48])
    sm.add(ctx.tuples[48:])
    sidx = ClusterIndex.from_result(sm.snapshot())
    some = ClusterIndex.from_result(res).clusters[0]
    hit = sidx.query(signature=some.signature)
    assert hit and hit[0].components == some.components
