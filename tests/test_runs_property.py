"""Properties of the sorted-run storage layer (``core.runs``):

* random interleavings of add / upsert / delete chunks mine exactly the
  batch re-mine of the canonicalised survivor set (last write wins,
  deletes tombstone every version) — kept clusters equal and kept
  cluster signatures bit-identical (same hash vectors), for the prime
  and NOAC variants alike,
* incremental snapshots are leaf-for-leaf bit-identical to the full
  device re-sort of the same survivor table at every interleaving,
* checkpoint → restore resumes a stream bit-identically to an
  uninterrupted one, restoring the run arrays themselves (only rows
  ingested *after* the restore are chunk-sorted — no O(T log T)
  rebuild), while legacy buffer-only blobs still restore via the lazy
  one-sort rebuild path.

The seeded drivers below always run; the hypothesis classes widen the
search in CI (the container has no hypothesis — same pattern as
``tests/test_keys_property.py``).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import BatchMiner, NOACMiner, StreamingMiner
from repro.core.postprocess import cluster_set
from repro.core.streaming import StreamState
from repro.data import synthetic  # noqa: F401  (kept for parity helpers)

DELTA = 50.0
SIZES = (7, 6, 5)


def _gen_ops(rng, sizes, n_ops, valued, universe=28, max_chunk=7):
    rows_u = np.stack([rng.integers(0, s, universe) for s in sizes],
                      1).astype(np.int32)
    ops = []
    for _ in range(n_ops):
        kind = rng.choice(["add", "add", "upsert", "delete"])
        m = int(rng.integers(1, max_chunk))
        rows = rows_u[rng.integers(0, universe, m)]
        vals = (rng.uniform(0.0, 100.0, m).astype(np.float32)
                if valued and kind != "delete" else None)
        ops.append((kind, rows, vals))
    return ops


def _survivors(ops, valued):
    """Python oracle of the canonicalised survivor set: one row per
    distinct tuple, last value winning (``core.context`` semantics);
    delete drops every version."""
    state = {}
    for kind, rows, vals in ops:
        for j in range(rows.shape[0]):
            key = tuple(int(x) for x in rows[j])
            if kind == "delete":
                state.pop(key, None)
            else:
                state[key] = float(vals[j]) if valued else 0.0
    if not state:
        return None, None
    rows = np.asarray(list(state.keys()), np.int32)
    vals = np.asarray(list(state.values()), np.float32) if valued else None
    return rows, vals


def _kept_sigs(res):
    keep = np.asarray(res.keep)
    return set(zip(np.asarray(res.sig_lo)[keep].tolist(),
                   np.asarray(res.sig_hi)[keep].tolist()))


def _assert_leaves_equal(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name)


def _run_ops(miner, ops):
    for kind, rows, vals in ops:
        getattr(miner, kind)(rows, vals) if kind != "delete" \
            else miner.delete(rows)
    return miner


def _check_interleaving(seed, n_ops, valued):
    rng = np.random.default_rng(seed)
    ops = _gen_ops(rng, SIZES, n_ops, valued)
    surv_rows, surv_vals = _survivors(ops, valued)
    sm = (StreamingMiner(SIZES, delta=DELTA) if valued
          else StreamingMiner(SIZES))
    _run_ops(sm, ops)
    if surv_rows is None:
        with pytest.raises(ValueError):
            sm.snapshot()
        return
    inc = sm.snapshot()
    full = sm.snapshot(full_remine=True)
    _assert_leaves_equal(inc, full)       # merge path ≡ device re-sort
    batch = (NOACMiner(SIZES, delta=DELTA)(surv_rows, surv_vals) if valued
             else BatchMiner(SIZES)(surv_rows))
    assert _kept_sigs(inc) == _kept_sigs(batch)
    assert (cluster_set(sm.materialise(inc))
            == cluster_set(sm.materialise(batch)))


def _check_checkpoint(seed, n_ops, valued, legacy=False):
    rng = np.random.default_rng(seed)
    ops = _gen_ops(rng, SIZES, n_ops, valued)
    cut = int(rng.integers(1, max(2, n_ops)))
    mk = (lambda: StreamingMiner(SIZES, delta=DELTA)) if valued \
        else (lambda: StreamingMiner(SIZES))
    whole = _run_ops(mk(), ops)
    first = _run_ops(mk(), ops[:cut])
    if first.state is None or first.state.count == 0:
        return
    blob = first.state.checkpoint()
    if legacy:    # pre-run-checkpoint blobs: buffer/count/values only
        blob = {k: blob[k] for k in ("buffer", "count", "values")
                if k in blob}
    resumed = mk()
    resumed.state = StreamState.restore(blob)
    _run_ops(resumed, ops[cut:])
    if _survivors(ops, valued)[0] is None:
        return
    _assert_leaves_equal(resumed.snapshot(), whole.snapshot())
    post = sum(r.shape[0] for k, r, _ in ops[cut:] if k != "delete")
    if not legacy and resumed.incremental:
        # the run arrays were restored: only post-restore arrivals were
        # chunk-sorted — resume is array loads, not a re-sort
        assert resumed.stats["chunk_sorted_rows"] <= post


@pytest.mark.parametrize("valued", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_interleavings_match_batch_survivors(seed, valued):
    _check_interleaving(seed, n_ops=12, valued=valued)


@pytest.mark.parametrize("valued", [False, True])
@pytest.mark.parametrize("seed", [10, 11])
def test_checkpoint_restore_equals_uninterrupted(seed, valued):
    _check_checkpoint(seed, n_ops=10, valued=valued)


@pytest.mark.parametrize("seed", [21])
def test_legacy_blob_lazy_rebuild(seed):
    _check_checkpoint(seed, n_ops=8, valued=True, legacy=True)


# ---------------------------------------------------------------------------
# Hypothesis widening (CI only; mirrors tests/test_keys_property.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover - CI installs it
    st = None

if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**16), st.integers(1, 20), st.booleans())
    def test_hypothesis_interleavings(seed, n_ops, valued):
        _check_interleaving(seed, n_ops, valued)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**16), st.integers(2, 14), st.booleans(),
           st.booleans())
    def test_hypothesis_checkpoint_restore(seed, n_ops, valued, legacy):
        _check_checkpoint(seed, n_ops, valued, legacy=legacy)
