"""Seam-adversarial properties of the windowed device pipeline
(``core.windowed``, DESIGN.md §3c):

* windowed ≡ monolithic **bit-for-bit** — every ``PipelineResult``
  leaf, permutations and signatures included — for prime and NOAC,
  across sort backends, and for budgets ∈ {tiny, exact divisor,
  non-divisor, == T, > T, None},
* the seam-carry contract survives adversarial layouts: a single key
  segment spanning ≥ 3 windows, NOAC δ-windows straddling window
  seams, duplicate rows split across seams,
* the engines that adopt the window budget (batch ``mine_windowed``,
  streaming snapshots, distributed serving snapshots, the engine
  registry's ``window_budget=`` param) all reproduce their monolithic
  twins exactly,
* the budget guards (ISSUE 9 satellite): sub-segment budgets are
  *exact* in both ``mine_chunked`` and ``mine_windowed`` — merged runs
  and seam carries make a segment larger than the budget safe, so the
  regression is "no silent seam split", not an error — while genuinely
  degenerate configurations (budget < 1, >64-bit keys, the lexsort
  baseline) raise clear errors instead of silently widening/splitting.

The seeded tests below always run; the hypothesis classes widen the
search in CI (the container has no hypothesis — same pattern as
``tests/test_radix_property.py``).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import BatchMiner, NOACMiner, StreamingMiner, mine
from repro.core import radix as RX
from repro.core import windowed as WD
from repro.core.context import PolyadicContext


def _assert_results_identical(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name)


def _random_ctx(rng, sizes, t, values):
    """Random context; valued contexts get UNIQUE tuples (V must be a
    function of the tuple, and the windowed path's run store treats a
    valued add as an upsert — duplicate rows would shrink the survivor
    table vs the raw monolithic call)."""
    if values:
        total = int(np.prod(sizes))
        t = min(t, total)
        flat = rng.choice(total, t, replace=False)
        tuples = np.stack(np.unravel_index(flat, sizes),
                          1).astype(np.int32)
        vals = rng.uniform(0.001, 1000.0, t).astype(np.float32)
        return tuples, vals
    tuples = np.stack([rng.integers(0, s, t, dtype=np.int32)
                       for s in sizes], 1)
    return tuples, None


def _giant_segment_ctx(t, values=False, seed=0):
    """A context where mode 2's key segment (the other two columns) is
    ONE segment covering the whole table — any budget < t forces that
    segment across every window seam.  The prime variant includes
    duplicate rows (they exercise the first-occurrence carry); the
    valued variant keeps tuples unique (see _random_ctx)."""
    rng = np.random.default_rng(seed)
    if values:
        e = rng.permutation(t).astype(np.int32)
        sizes = (2, 2, t)
        vals = rng.uniform(0.0, 10.0, t).astype(np.float32)
    else:
        e = rng.integers(0, max(2, t // 2), t, dtype=np.int32)  # dups
        sizes = (2, 2, max(2, t // 2))
        vals = None
    tuples = np.stack([np.zeros(t, np.int32), np.zeros(t, np.int32), e], 1)
    return sizes, tuples, vals


# ---------------------------------------------------------------------------
# Bit-identity across budgets, backends, variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["radix", "lax"])
@pytest.mark.parametrize("budget", [1, 7, 60, 120, 121, 500, None])
def test_windowed_prime_bit_identical(backend, budget):
    sizes = (9, 7, 5)
    rng = np.random.default_rng(3)
    tuples, _ = _random_ctx(rng, sizes, 120, values=False)
    bm = BatchMiner(sizes, sort_backend=backend)
    _assert_results_identical(
        bm(tuples), bm.mine_windowed(tuples, window_budget=budget))


@pytest.mark.parametrize("backend", ["radix", "lax"])
@pytest.mark.parametrize("budget", [1, 13, 50, 100, 777, None])
@pytest.mark.parametrize("delta", [0.0, 50.0])
def test_windowed_noac_bit_identical(backend, budget, delta):
    sizes = (7, 6, 5)
    rng = np.random.default_rng(11)
    tuples, vals = _random_ctx(rng, sizes, 100, values=True)
    nm = NOACMiner(sizes, delta=delta, sort_backend=backend)
    _assert_results_identical(
        nm(tuples, vals),
        nm.mine_windowed(tuples, values=vals, window_budget=budget))


def test_windowed_matches_every_monolithic_backend():
    """The windowed path (one result) equals the monolithic pipeline
    under ALL sort backends — lexsort included (the backends are
    mutually bit-identical, so windowed must match each of them)."""
    sizes = (8, 6, 4)
    rng = np.random.default_rng(5)
    tuples, vals = _random_ctx(rng, sizes, 90, values=True)
    win = NOACMiner(sizes, delta=10.0).mine_windowed(
        tuples, values=vals, window_budget=17)
    for backend in ("radix", "lax", "lexsort"):
        mono = NOACMiner(sizes, delta=10.0, sort_backend=backend,
                         prune_values=False)(tuples, vals)
        _assert_results_identical(mono, win)


# ---------------------------------------------------------------------------
# Seam-adversarial layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [5, 16, 49])
def test_single_segment_spans_many_windows(budget):
    """One key segment covering the whole table: with budget 5 at
    T=200 the segment spans 40 windows; the masked-prefix seam carry
    must reassemble it exactly (signatures, cardinalities, bounds)."""
    sizes, tuples, _ = _giant_segment_ctx(200, seed=1)
    assert -(-200 // budget) >= 3
    bm = BatchMiner(sizes)
    _assert_results_identical(
        bm(tuples), bm.mine_windowed(tuples, window_budget=budget))


@pytest.mark.parametrize("budget", [7, 32])
def test_delta_window_straddles_seams(budget):
    """δ large enough that every tuple's value window covers most of
    the (single, table-spanning) segment — the δ-range bounds and the
    prefix differences both cross many window seams."""
    sizes, tuples, vals = _giant_segment_ctx(150, values=True, seed=2)
    nm = NOACMiner(sizes, delta=5.0)
    _assert_results_identical(
        nm(tuples, vals),
        nm.mine_windowed(tuples, values=vals, window_budget=budget))


def test_duplicate_rows_across_seams():
    """Duplicate rows adjacent in sorted order but split by a window
    seam: the carried first-occurrence comparison must mask the copy
    in the next window (and tfirst/stage-3 dedup must agree)."""
    sizes = (4, 3, 3)
    rng = np.random.default_rng(7)
    base, _ = _random_ctx(rng, sizes, 30, values=False)
    tuples = np.concatenate([base, base, base[:11]], 0)  # heavy dups
    bm = BatchMiner(sizes)
    for budget in (1, 2, 9):
        _assert_results_identical(
            bm(tuples), bm.mine_windowed(tuples, window_budget=budget))


# ---------------------------------------------------------------------------
# Engine adoption (registry param, streaming + distributed snapshots)
# ---------------------------------------------------------------------------

def _ctx(sizes, tuples, vals=None):
    return PolyadicContext(sizes, tuples, vals)


def test_engine_registry_window_budget():
    sizes = (9, 7, 5)
    rng = np.random.default_rng(13)
    tuples, vals = _random_ctx(rng, sizes, 160, values=True)
    for variant, v in (("prime", None), ("noac", vals)):
        kw = {} if variant == "prime" else {"delta": 2.0}
        ctx = _ctx(sizes, tuples, v)
        mono = mine(ctx, backend="batch", variant=variant, **kw)
        win = mine(ctx, backend="batch", variant=variant,
                   window_budget=23, **kw)
        _assert_results_identical(mono.result, win.result)
        assert mono.n_clusters == win.n_clusters


def test_streaming_snapshot_windowed():
    sizes = (9, 7, 5)
    rng = np.random.default_rng(17)
    tuples, vals = _random_ctx(rng, sizes, 150, values=True)
    ref = StreamingMiner(sizes, delta=3.0)
    win = StreamingMiner(sizes, delta=3.0, window_budget=31)
    for lo in range(0, 150, 50):
        ref.add(tuples[lo:lo + 50], vals[lo:lo + 50])
        win.add(tuples[lo:lo + 50], vals[lo:lo + 50])
    _assert_results_identical(ref.snapshot(), win.snapshot())


def test_distributed_serving_snapshot_windowed():
    sizes = (9, 7, 5)
    rng = np.random.default_rng(19)
    tuples, _ = _random_ctx(rng, sizes, 128, values=False)
    ctx = _ctx(sizes, tuples)
    ref = mine(ctx, backend="distributed", variant="prime",
               incremental=True)
    win = mine(ctx, backend="distributed", variant="prime",
               incremental=True, window_budget=19)
    _assert_results_identical(ref.miner.serving_snapshot(),
                              win.miner.serving_snapshot())


# ---------------------------------------------------------------------------
# Budget guards (satellite: no silent seam split, loud degenerate cases)
# ---------------------------------------------------------------------------

def test_sub_segment_budget_is_exact_not_split():
    """Regression: a budget smaller than the largest segment's row
    count must NOT silently split the segment — both out-of-core paths
    stay bit-exact (merged runs / seam carries)."""
    sizes, tuples, vals = _giant_segment_ctx(120, values=True, seed=23)
    nm = NOACMiner(sizes, delta=1.0, prune_values=False)
    mono = nm(tuples, vals)
    # largest segment = 120 rows; budget 11 is far below it
    _assert_results_identical(
        mono, nm.mine_chunked(tuples, values=vals, chunk_budget=11))
    _assert_results_identical(
        mono, nm.mine_windowed(tuples, values=vals, window_budget=11))


@pytest.mark.parametrize("budget", [0, -3])
def test_degenerate_budgets_raise(budget):
    sizes = (4, 3, 3)
    rng = np.random.default_rng(29)
    tuples, _ = _random_ctx(rng, sizes, 20, values=False)
    bm = BatchMiner(sizes)
    with pytest.raises(ValueError, match="window_budget"):
        bm.mine_windowed(tuples, window_budget=budget)
    with pytest.raises(ValueError, match="chunk_budget"):
        bm.mine_chunked(tuples, chunk_budget=budget)
    with pytest.raises(ValueError, match="window_budget"):
        RX.plan_windows(20, budget)


def test_windowed_rejects_lexsort_and_oversized_keys():
    sizes = (4, 3, 3)
    rng = np.random.default_rng(31)
    tuples, _ = _random_ctx(rng, sizes, 20, values=False)
    with pytest.raises(ValueError, match="lexsort"):
        BatchMiner(sizes, packed=False).mine_windowed(tuples,
                                                      window_budget=5)
    big = (1 << 20, 1 << 20, 1 << 20, 1 << 20)   # 80-bit key
    rows = np.stack([rng.integers(0, 64, 10, dtype=np.int32)
                     for _ in big], 1)
    with pytest.raises(ValueError, match="64"):
        BatchMiner(big).mine_windowed(rows, window_budget=5)


def test_plan_windows_shared_unit():
    p = RX.plan_windows(100, 32)
    assert p.n_windows == 4
    assert p.bounds[0] == (0, 32) and p.bounds[-1] == (96, 100)
    assert RX.plan_windows(100, None).n_windows == 1
    assert RX.plan_windows(100, 1000).budget == 100


# ---------------------------------------------------------------------------
# Hypothesis widening (CI only; mirrors tests/test_radix_property.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover - CI installs it
    st = None

if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 7), st.integers(2, 7), st.integers(2, 7),
           st.integers(1, 60), st.integers(1, 70), st.integers(0, 2**16),
           st.one_of(st.none(), st.floats(0.0, 500.0)),
           st.sampled_from(["radix", "lax"]))
    def test_hypothesis_windowed_bit_identical(a, b, c, t, budget, seed,
                                               delta, backend):
        sizes = (a, b, c)
        rng = np.random.default_rng(seed)
        tuples, vals = _random_ctx(rng, sizes, t, values=delta is not None)
        if delta is None:
            m = BatchMiner(sizes, sort_backend=backend)
            _assert_results_identical(
                m(tuples), m.mine_windowed(tuples, window_budget=budget))
        else:
            m = NOACMiner(sizes, delta=delta, sort_backend=backend)
            _assert_results_identical(
                m(tuples, vals),
                m.mine_windowed(tuples, values=vals, window_budget=budget))
