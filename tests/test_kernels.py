"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 4, 4, 128, 128, 64),      # MHA square
    (2, 8, 2, 128, 256, 64),      # GQA, kv longer (prefill continuation)
    (1, 4, 1, 64, 128, 128),      # MQA, sq not multiple of default bq
    (1, 2, 2, 200, 200, 32),      # ragged: padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, hq, hkv, sq, skv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, hq, sq, d), dtype)
    k = _rand(ks[1], (b, hkv, skv, d), dtype)
    v = _rand(ks[2], (b, hkv, skv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [32, 128, None])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_masks(window, causal):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, h, s, d = 1, 2, 256, 64
    q = _rand(ks[0], (b, h, s, d), jnp.float32)
    k = _rand(ks[1], (b, h, s, d), jnp.float32)
    v = _rand(ks[2], (b, h, s, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_q_offset():
    """Chunked prefill: q rows are a suffix of the kv range."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, h, d = 1, 2, 64
    skv, sq = 256, 64
    q = _rand(ks[0], (b, h, sq, d), jnp.float32)
    k = _rand(ks[1], (b, h, skv, d), jnp.float32)
    v = _rand(ks[2], (b, h, skv, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=skv - sq,
                              bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=skv - sq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d,kv_len,window", [
    (2, 4, 2, 512, 64, 512, None),
    (1, 8, 8, 1024, 64, 700, None),    # padded cache
    (2, 4, 1, 512, 128, 512, 128),     # sliding window
    (1, 2, 2, 300, 32, 300, None),     # ragged skv
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, hq, hkv, s, d, kv_len, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (b, hq, d), dtype)
    k = _rand(ks[1], (b, hkv, s, d), dtype)
    v = _rand(ks[2], (b, hkv, s, d), dtype)
    out = ops.decode_attention(q, k, v, kv_len=kv_len, window=window, bk=256)
    want = ref.decode_attention_ref(q, k, v, kv_len=kv_len, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (2, 3, 128), (256, 512), (5, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = _rand(ks[0], shape, dtype)
    w = _rand(ks[1], shape[-1:], jnp.float32) + 1.0
    out = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# signature
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e", [(8, 128), (16, 512), (256, 1024), (3, 77)])
def test_signature(t, e):
    rng = np.random.default_rng(5)
    mask = jnp.asarray(rng.integers(0, 2, (t, e)), jnp.uint32)
    r = jnp.asarray(rng.integers(1, 2**32, e, dtype=np.uint32))
    out = ops.set_signature(mask, r)
    want = ref.signature_ref(mask, r)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_signature_order_independent():
    rng = np.random.default_rng(6)
    e = 128
    r = jnp.asarray(rng.integers(1, 2**32, e, dtype=np.uint32))
    m1 = np.zeros((8, e), np.uint32)
    m1[:, rng.choice(e, 20, replace=False)] = 1
    s1 = ops.set_signature(jnp.asarray(m1), r)
    assert len(set(np.asarray(s1).tolist())) == 1  # identical sets hash equal


# ---------------------------------------------------------------------------
# tricluster density
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,m,b,t", [(8, 16, 16, 8), (16, 8, 32, 128),
                                     (7, 5, 9, 3)])
def test_tricluster_density(g, m, b, t):
    rng = np.random.default_rng(7)
    tensor = jnp.asarray(rng.integers(0, 2, (g, m, b)), jnp.float32)
    x = jnp.asarray(rng.integers(0, 2, (t, g)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, (t, m)), jnp.float32)
    z = jnp.asarray(rng.integers(0, 2, (t, b)), jnp.float32)
    out = ops.tricluster_density(tensor, x, y, z)
    want = ref.tricluster_density_ref(tensor, x, y, z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_exact_density_against_brute_force():
    """Kernel numerator equals a literal triple-loop box count."""
    rng = np.random.default_rng(8)
    g, m, b, t = 6, 7, 8, 4
    tensor = rng.integers(0, 2, (g, m, b))
    x = rng.integers(0, 2, (t, g))
    y = rng.integers(0, 2, (t, m))
    z = rng.integers(0, 2, (t, b))
    want = np.zeros(t)
    for ti in range(t):
        for gi in range(g):
            for mi in range(m):
                for bi in range(b):
                    want[ti] += (x[ti, gi] * y[ti, mi] * z[ti, bi]
                                 * tensor[gi, mi, bi])
    out = ops.tricluster_density(jnp.asarray(tensor, jnp.float32),
                                 jnp.asarray(x, jnp.float32),
                                 jnp.asarray(y, jnp.float32),
                                 jnp.asarray(z, jnp.float32))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


# ---------------------------------------------------------------------------
# fused segment reduce (masked prefix sums)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,bt", [(8, 8), (40, 16), (100, 32), (1024, 256),
                                  (5000, 1024)])
def test_segment_reduce(t, bt):
    rng = np.random.default_rng(9)
    w_lo = jnp.asarray(rng.integers(0, 2**32, t, dtype=np.uint32))
    w_hi = jnp.asarray(rng.integers(0, 2**32, t, dtype=np.uint32))
    first = jnp.asarray(rng.random(t) < 0.6)
    got = ops.segment_reduce(w_lo, w_hi, first, bt=bt)
    want = ref.segment_reduce_ref(w_lo, w_hi, first)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_segment_reduce_uint32_wraparound():
    """Prefix sums must wrap mod 2^32 exactly (range differences of the
    mining signatures rely on modular arithmetic)."""
    w = jnp.full((64,), 0xFFFFFFFF, jnp.uint32)
    f = jnp.ones((64,), bool)
    lo, hi, cnt = ops.segment_reduce(w, w, f, bt=16)
    want = np.cumsum(np.full(64, 0xFFFFFFFF, np.uint64)).astype(np.uint32)
    np.testing.assert_array_equal(np.asarray(lo), want)
    np.testing.assert_array_equal(np.asarray(cnt), np.arange(1, 65))


def test_segment_reduce_in_pipeline():
    """The fused kernel (interpret mode on CPU) is bit-identical to the
    jnp oracle through the full mining pipeline, both variants."""
    from repro.core import BatchMiner, NOACMiner
    from repro.data import synthetic
    ctx = synthetic.random_context((7, 6, 5), 64, seed=3)
    a = BatchMiner(ctx.sizes, use_pallas=True)(ctx.tuples)
    b = BatchMiner(ctx.sizes, use_pallas=False)(ctx.tuples)
    np.testing.assert_array_equal(np.asarray(a.sig_lo), np.asarray(b.sig_lo))
    np.testing.assert_array_equal(np.asarray(a.gen_count),
                                  np.asarray(b.gen_count))
    ctxv = synthetic.random_context((7, 6, 5), 64, seed=4,
                                    values=True).deduplicated()
    av = NOACMiner(ctxv.sizes, delta=60.0, use_pallas=True)(
        ctxv.tuples, ctxv.values)
    bv = NOACMiner(ctxv.sizes, delta=60.0, use_pallas=False)(
        ctxv.tuples, ctxv.values)
    np.testing.assert_array_equal(np.asarray(av.sig_lo),
                                  np.asarray(bv.sig_lo))
    np.testing.assert_array_equal(np.asarray(av.density),
                                  np.asarray(bv.density))


# ---------------------------------------------------------------------------
# radix sort primitives (one-sweep histograms + per-pass stable ranks)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,bt,live", [(8, 8, 5), (100, 32, 22),
                                       (513, 128, 28), (1024, 256, 60),
                                       (2000, 512, 64)])
def test_radix_histogram(t, bt, live):
    from repro.core.radix import plan_radix
    rng = np.random.default_rng(t)
    keys = rng.integers(0, 1 << min(live, 63), t, dtype=np.uint64)
    words = ([jnp.asarray((keys >> np.uint64(32)).astype(np.uint32)),
              jnp.asarray(keys.astype(np.uint32))] if live > 32
             else [jnp.asarray(keys.astype(np.uint32))])
    plan = plan_radix(live, t, digit_bits=8)
    got = ops.radix_histogram(words, plan.shifts, plan.widths, bt=bt)
    want = ref.radix_histogram_ref(words, plan.shifts, plan.widths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.asarray(got).sum()) == t * plan.passes


@pytest.mark.parametrize("t,bt", [(8, 8), (100, 32), (513, 128),
                                  (2000, 512)])
def test_radix_rank(t, bt):
    rng = np.random.default_rng(t + 1)
    dig = rng.integers(0, 256, t).astype(np.uint32)
    hist = np.bincount(dig, minlength=256)
    starts = jnp.asarray(np.concatenate([[0], np.cumsum(hist)[:-1]])
                         .astype(np.int32))
    digits = jnp.asarray(dig)
    got = ops.radix_rank(digits, starts, bt=bt)
    want = ref.radix_rank_ref(digits, starts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ranks are the stable counting-sort permutation: bijective and
    # digit-ordered, ties in input order
    r = np.asarray(got)
    assert sorted(r.tolist()) == list(range(t))
    assert (dig[np.argsort(r)] == np.sort(dig, kind="stable")).all()
