"""Unified pipeline: NOAC through the distributed and streaming engines
(vs the pure-python oracle and vs single-shard, bit-identically), plus
the engine registry front-end.

Multi-device (8 simulated hosts) parity for both variants and both merge
strategies runs in the subprocess of
``test_core_distributed.py::test_multidevice_subprocess``."""
import numpy as np
import pytest

from repro.core import (BatchMiner, DistributedMiner, NOACMiner,
                        StreamingMiner, available_engines, mine, pad_tuples,
                        pad_values, resolve_engine)
from repro.core import reference as ref
from repro.core.context import PolyadicContext
from repro.core.postprocess import cluster_set
from repro.data import synthetic
from repro.launch.mesh import make_mesh


def _noac_oracle(ctx, delta, rho_min=0.0, minsup=0):
    out = ref.noac(ctx.deduplicated(), delta, rho_min=rho_min, minsup=minsup)
    return {tuple(tuple(sorted(c)) for c in cl) for cl in out}


@pytest.mark.parametrize("strategy", ["replicate", "shuffle"])
def test_noac_distributed_parity(strategy):
    """NOAC on the shard_map engine: bit-identical signatures to the
    single-shard NOACMiner and kept-cluster count equal to the oracle."""
    mesh = make_mesh((1,), ("data",))
    ctx = synthetic.random_context((8, 6, 5), 96, seed=0,
                                   values=True).deduplicated()
    delta, rho, minsup = 75.0, 0.3, 2
    tuples = pad_tuples(ctx.tuples, 1)
    values = pad_values(ctx.values, 1)
    nm = NOACMiner(ctx.sizes, delta=delta, rho_min=rho, minsup=minsup)
    want = nm(tuples, values)
    dm = DistributedMiner(ctx.sizes, mesh, axes="data", strategy=strategy,
                          delta=delta, rho_min=rho, minsup=minsup)
    got = dm(tuples, values)
    assert int(got.overflow) == 0
    np.testing.assert_array_equal(np.asarray(got.sig_lo),
                                  np.asarray(want.sig_lo))
    np.testing.assert_array_equal(np.asarray(got.sig_hi),
                                  np.asarray(want.sig_hi))
    np.testing.assert_array_equal(np.asarray(got.gen_count),
                                  np.asarray(want.gen_count))
    np.testing.assert_allclose(np.asarray(got.density),
                               np.asarray(want.density), rtol=1e-6)
    assert (int(np.asarray(got.keep).sum())
            == int(np.asarray(want.keep).sum())
            == len(_noac_oracle(ctx, delta, rho, minsup)))


def test_noac_distributed_duplicate_padding():
    """Shard padding duplicates rows; the δ-pipeline must be idempotent."""
    mesh = make_mesh((1,), ("data",))
    ctx = synthetic.random_context((6, 5, 4), 61, seed=1,
                                   values=True).deduplicated()
    dm = DistributedMiner(ctx.sizes, mesh, delta=50.0)
    got = dm(pad_tuples(ctx.tuples, 8), pad_values(ctx.values, 8))
    assert (int(np.asarray(got.keep).sum())
            == len(_noac_oracle(ctx, 50.0)))


@pytest.mark.parametrize("delta,rho,minsup", [(0.0, 0.0, 0),
                                              (60.0, 0.0, 0),
                                              (60.0, 0.4, 2)])
def test_noac_streaming_incremental_snapshots(delta, rho, minsup):
    """Incremental (sorted-run merge) snapshots at several chunk
    boundaries: exactly the oracle, and bit-identical to a full re-mine
    of the buffer."""
    ctx = synthetic.random_context((7, 6, 5), 96, seed=2,
                                   values=True).deduplicated()
    sm = StreamingMiner(ctx.sizes, delta=delta, rho_min=rho, minsup=minsup)
    assert sm.incremental, "key codec must fit for this context"
    chunk = 24
    for lo in range(0, ctx.num_tuples, chunk):
        sm.add(ctx.tuples[lo:lo + chunk], ctx.values[lo:lo + chunk])
        seen = PolyadicContext(ctx.sizes, ctx.tuples[:lo + chunk],
                               ctx.values[:lo + chunk])
        inc = sm.snapshot()
        full = sm.snapshot(full_remine=True)
        np.testing.assert_array_equal(np.asarray(inc.sig_lo),
                                      np.asarray(full.sig_lo))
        np.testing.assert_array_equal(np.asarray(inc.gen_count),
                                      np.asarray(full.gen_count))
        got = cluster_set(sm.materialise(inc))
        assert got == _noac_oracle(seen, delta, rho, minsup)
    assert sm.stats["chunk_sorted_rows"] == ctx.num_tuples


def test_prime_streaming_incremental_bit_identical():
    """Prime variant: merged-permutation snapshots equal device re-sorts
    bit-for-bit, while only chunks were host-sorted."""
    ctx = synthetic.random_context((9, 8, 7), 160, seed=3)
    sm = StreamingMiner(ctx.sizes)
    bm = BatchMiner(ctx.sizes)
    for lo in range(0, 160, 40):
        sm.add(ctx.tuples[lo:lo + 40])
        inc = sm.snapshot()
        full = sm.snapshot(full_remine=True)
        for f in ("sig_lo", "sig_hi", "gen_count", "volume"):
            np.testing.assert_array_equal(np.asarray(getattr(inc, f)),
                                          np.asarray(getattr(full, f)))
        seen = PolyadicContext(ctx.sizes, ctx.tuples[:lo + 40])
        assert (cluster_set(sm.materialise(inc))
                == cluster_set(bm.mine_context(seen)))
    assert sm.stats["chunk_sorted_rows"] == 160
    assert sm.stats["full_resorts"] == 4  # only the explicit baselines


def _kept_sigs(res):
    keep = np.asarray(res.keep)
    return set(zip(np.asarray(res.sig_lo)[keep].tolist(),
                   np.asarray(res.sig_hi)[keep].tolist()))


@pytest.mark.parametrize("variant", ["prime", "noac"])
def test_mine_chunked_bit_identical_to_in_core(variant):
    """Out-of-core chunked Stage 1 (host run store + merged perms) is
    leaf-for-leaf bit-identical to one-shot in-core mining — ≥4 chunks,
    both variants."""
    import dataclasses
    if variant == "prime":
        ctx = synthetic.random_context((9, 8, 7), 200, seed=6)
        miner = BatchMiner(ctx.sizes)
        vals = None
    else:
        ctx = synthetic.random_context((8, 7, 6), 160, seed=7,
                                       values=True).deduplicated()
        miner = NOACMiner(ctx.sizes, delta=60.0, rho_min=0.2, minsup=1)
        vals = ctx.values
    in_core = miner(ctx.tuples) if vals is None \
        else miner(ctx.tuples, vals)
    budget = -(-ctx.num_tuples // 5)          # 5 chunks
    stats = {}
    chunked = miner.mine_chunked(ctx.tuples, values=vals,
                                 chunk_budget=budget, stats=stats)
    for f in dataclasses.fields(in_core):
        np.testing.assert_array_equal(
            np.asarray(getattr(in_core, f.name)),
            np.asarray(getattr(chunked, f.name)), err_msg=f.name)
    assert stats["chunk_sorted_rows"] == ctx.num_tuples


@pytest.mark.parametrize("variant", ["prime", "noac"])
def test_incremental_distributed_snapshots(variant):
    """Trickle ingestion into per-shard run stores: every snapshot's
    kept clusters/signatures are bit-identical to one-shot batch mining
    of the seen context AND to the streaming engine's snapshot; repeated
    snapshots merge runs instead of re-sorting every shard."""
    mesh = make_mesh((1,), ("data",))
    if variant == "prime":
        ctx = synthetic.random_context((9, 8, 7), 160, seed=8)
        dm = DistributedMiner(ctx.sizes, mesh)
        sm = StreamingMiner(ctx.sizes)
        bm = BatchMiner(ctx.sizes)
        vals = None
    else:
        ctx = synthetic.random_context((8, 7, 6), 120, seed=9,
                                       values=True).deduplicated()
        kw = dict(delta=60.0, rho_min=0.2, minsup=1)
        dm = DistributedMiner(ctx.sizes, mesh, **kw)
        sm = StreamingMiner(ctx.sizes, **kw)
        bm = NOACMiner(ctx.sizes, **kw)
        vals = ctx.values
    chunk = -(-ctx.num_tuples // 4)
    for lo in range(0, ctx.num_tuples, chunk):
        hi = lo + chunk
        v = None if vals is None else vals[lo:hi]
        dm.ingest(ctx.tuples[lo:hi], v)
        sm.add(ctx.tuples[lo:hi], v)
        seen_v = None if vals is None else vals[:hi]
        want = _kept_sigs(bm(ctx.tuples[:hi]) if seen_v is None
                          else bm(ctx.tuples[:hi], seen_v))
        inc = dm.snapshot()
        assert _kept_sigs(inc) == want
        assert _kept_sigs(sm.snapshot()) == want       # streaming parity
        assert _kept_sigs(dm.snapshot(full_remine=True)) == want
    st = dm.stream_stats
    assert st["chunk_sorted_rows"] == ctx.num_tuples   # chunks only
    assert st["merged_rows"] > 0 and st["full_resorts"] == 4
    assert st["incremental"]


def test_registry_chunk_budget_and_incremental_knobs():
    ctx = synthetic.random_context((6, 5, 4), 64, seed=10, values=True)
    base = mine(ctx, backend="batch", variant="noac", delta=40.0)
    ooc = mine(ctx, backend="batch", variant="noac", delta=40.0,
               chunk_budget=16)
    incd = mine(ctx, backend="distributed", variant="noac", delta=40.0,
                incremental=True, chunks=4)
    assert base.n_clusters == ooc.n_clusters == incd.n_clusters
    assert incd.miner.stream_stats["snapshots"] >= 1


def test_registry_backends_agree():
    ctx = synthetic.random_context((6, 5, 4), 64, seed=4, values=True)
    runs = {b: mine(ctx, backend=b, variant="noac", delta=40.0)
            for b in ("batch", "streaming", "reference", "distributed")}
    counts = {b: r.n_clusters for b, r in runs.items()}
    assert len(set(counts.values())) == 1, counts
    sets = {b: cluster_set(r.clusters) for b, r in runs.items()
            if r.clusters is not None}
    assert len(set(map(frozenset, sets.values()))) == 1


def test_registry_unknown_combination_lists_choices():
    ctx = synthetic.random_context((4, 4, 4), 16, seed=5)
    with pytest.raises(ValueError, match="batch/prime"):
        mine(ctx, backend="spark", variant="prime")
    with pytest.raises(ValueError, match="delta"):
        mine(ctx, backend="batch", variant="noac")
    with pytest.raises(ValueError, match="valid"):
        resolve_engine("batch", "fuzzy")
    assert ("distributed", "noac") in available_engines()


def test_launcher_rejects_unknown_backend(capsys):
    from repro.launch import tricluster as tri
    assert tri.main(["--dataset", "random", "--n-tuples", "64",
                     "--backend", "hadoop"]) == 2
    err = capsys.readouterr().err
    assert "valid backend/variant choices" in err and "batch/prime" in err
