"""Unified observability plane (ISSUE 10): histogram bucket math vs
exact quantiles, registry thread-safety under concurrent writers,
trace-id propagation across a live 2-shard router fan-out (including an
injected retry and a degraded drop, stitched by one trace id across
three processes), and the metrics-disabled path producing zero
spans/samples.

The live test follows the chaos-test conventions of
``test_serve_faults.py``: every fault fires on a logical request
counter (seeded :class:`FaultPlan`), and every assertion synchronises
on an observable state transition with a bounded wait.
"""
import json
import math
import os
import re
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.obs import (NULL_OBS, TRACE_HEADER, Histogram, NullInstrument,
                       Obs, Registry, SlowQueryLog, Tracer,
                       format_trace_header, parse_trace_header)
from repro.serve.faults import FaultPlan
from repro.serve.protocol import make_server
from repro.serve.service import TriclusterService

SIZES = (24, 12, 8)


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    return env


def _wait_for(cond, timeout=30.0, what="condition"):
    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() >= deadline:
            raise TimeoutError(f"{what} not reached in {timeout}s")
        time.sleep(0.01)


def _service(seed=3, n=160, **kw):
    rng = np.random.default_rng(seed)
    svc = TriclusterService(SIZES, refresh_interval=0.05,
                            dirty_threshold=4, seed=seed, **kw)
    svc.add(rng.integers(0, SIZES, size=(n, 3)).astype(np.int64))
    return svc


def _serve(svc, obs=None):
    server = make_server(svc, port=0, obs=obs)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    return server


def _get_text(url, timeout=10.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read().decode()


def _get_json(url, timeout=10.0):
    return json.loads(_get_text(url, timeout))


def _post_json(url, doc, timeout=10.0, headers=None):
    hdrs = {"Content-Type": "application/json"}
    if headers:
        hdrs.update(headers)
    req = urllib.request.Request(url, data=json.dumps(doc).encode(),
                                 headers=hdrs)
    with urllib.request.urlopen(req, timeout=timeout) as r:
        return json.loads(r.read().decode())


# ---------------------------------------------------------------------------
# Histogram bucket math vs exact quantiles
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_bucket_quantiles_track_exact_order_statistics(self):
        """The geometric-bucket estimate must sit within the documented
        relative error bound — ``sqrt(ratio) - 1`` — of the exact order
        statistic at the same rank, across a heavy-tailed sample."""
        rng = np.random.default_rng(7)
        samples = np.sort(rng.lognormal(mean=2.0, sigma=1.2, size=5000))
        h = Histogram()
        for v in rng.permutation(samples):
            h.observe(float(v))
        tol = math.sqrt(h.ratio) - 1.0
        for q in (0.10, 0.50, 0.90, 0.99):
            exact = float(samples[int(math.floor(q * (len(samples) - 1)))])
            est = h.quantile(q)
            assert est is not None
            assert abs(est - exact) / exact <= tol + 1e-9, \
                f"q={q}: est {est} vs exact {exact}"

    def test_estimates_clamped_to_observed_range(self):
        h = Histogram()
        for v in (5.0, 7.0, 11.0):
            h.observe(v)
        tol = math.sqrt(h.ratio) - 1.0
        # min/max are tracked exactly and clamp every bucket estimate
        for q in (0.0, 0.25, 0.5, 0.75, 1.0):
            assert 5.0 <= h.quantile(q) <= 11.0
        assert abs(h.quantile(0.0) - 5.0) / 5.0 <= tol
        assert abs(h.quantile(1.0) - 11.0) / 11.0 <= tol
        assert h.count == 3
        assert h.sum == 23.0

    def test_underflow_and_overflow_buckets(self):
        h = Histogram(lo=1.0, hi=10.0)
        h.observe(0.0)        # below lo (underflow bucket)
        h.observe(1e6)        # above hi (overflow bucket)
        assert h.count == 2
        assert h.quantile(0.0) == 1.0     # underflow represented as lo
        assert h.quantile(1.0) == 1e6     # overflow uses the exact max
        snap = h.snapshot()
        assert snap["count"] == 2 and snap["min"] == 0.0
        assert snap["buckets"][-1][0] == math.inf

    def test_empty_and_bad_q(self):
        h = Histogram()
        assert h.quantile(0.5) is None
        assert h.percentiles() == {"p50": None, "p99": None}
        with pytest.raises(ValueError):
            h.quantile(1.5)


# ---------------------------------------------------------------------------
# Registry: thread-safety, kind binding, collectors, exposition
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_concurrent_writers_lose_nothing(self):
        reg = Registry()
        n_threads, n_iter = 8, 400
        errors = []

        def work(i):
            try:
                for j in range(n_iter):
                    # re-enter the registry every time: the memoised
                    # lookup path is part of what must be thread-safe
                    reg.counter("hits", worker=i % 2).inc()
                    reg.histogram("lat_ms").observe(float(j + 1))
                    reg.gauge("depth", worker=i % 2).set(j)
            except Exception as e:             # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        total = (reg.counter("hits", worker=0).value
                 + reg.counter("hits", worker=1).value)
        assert total == n_threads * n_iter
        h = reg.histogram("lat_ms")
        assert h.count == n_threads * n_iter
        assert h.quantile(0.0) == 1.0
        tol = math.sqrt(h.ratio) - 1.0
        assert abs(h.quantile(1.0) - n_iter) / n_iter <= tol
        text = reg.expose()
        assert 'repro_hits{worker="0"}' in text
        assert f"repro_lat_ms_count {n_threads * n_iter}" in text

    def test_name_bound_to_one_kind(self):
        reg = Registry()
        reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")

    def test_collector_folds_and_filters(self):
        reg = Registry()
        reg.register_collector(lambda: [
            ("stat_a", {"role": "writer"}, 3),
            ("stat_inf", {}, float("inf")),     # non-finite: dropped
            ("stat_str", {}, "nope"),           # non-numeric: dropped
            ("stat_flag", {}, True),            # bool → 1.0
        ])
        text = reg.expose()
        assert 'repro_stat_a{role="writer"} 3.0' in text
        assert "stat_inf" not in text and "stat_str" not in text
        assert "repro_stat_flag 1.0" in text
        # collectors never mutate instruments: still zero native samples
        assert reg.sample_count() == 0

    def test_broken_collector_does_not_break_scrape(self):
        reg = Registry()
        reg.register_collector(lambda: (_ for _ in ()).throw(RuntimeError))
        reg.counter("ok").inc()
        assert "repro_ok 1.0" in reg.expose()


# ---------------------------------------------------------------------------
# Trace spans, header contract, slow-query log
# ---------------------------------------------------------------------------

class TestTrace:
    def test_header_round_trip_and_malformed(self):
        assert parse_trace_header(
            format_trace_header("ab12", "cd34")) == ("ab12", "cd34")
        assert parse_trace_header("ab12") == ("ab12", None)
        for bad in (None, "", 42, "XYZ/1", "/orphan", "  /  "):
            assert parse_trace_header(bad) == (None, None)

    def test_span_parentage_and_ring_bound(self):
        tr = Tracer(service="t", ring=16)
        with tr.span("root") as root:
            child = tr.start("child", trace_id=root.trace_id,
                             parent_id=root.span_id)
            child.set("k", 1).finish()
        spans = tr.spans(root.trace_id)
        assert [s["name"] for s in spans] == ["child", "root"]
        assert spans[0]["parent_id"] == root.span_id
        assert spans[0]["attrs"]["k"] == 1
        assert spans[1]["parent_id"] is None
        assert all(s["pid"] == os.getpid() for s in spans)
        for _ in range(40):
            with tr.span("filler"):
                pass
        assert len(tr) == 16 and tr.dropped > 0

    def test_ctx_manager_marks_exceptions(self):
        tr = Tracer()
        with pytest.raises(KeyError):
            with tr.span("boom"):
                raise KeyError("k")
        (sp,) = tr.spans()
        assert sp["status"] == "error" and "KeyError" in sp["attrs"]["error"]

    def test_slow_log_keeps_n_slowest(self):
        log = SlowQueryLog(threshold_ms=10.0, keep=3)
        assert not log.record("/query", 5.0)       # under threshold
        for ms in (20.0, 40.0, 30.0, 50.0, 25.0):
            log.record("/query", ms, handler_ms=ms - 1.0, wait_ms=1.0,
                       trace_id=f"t{int(ms)}", coverage=[0, 1])
        ents = log.entries()
        assert [e["total_ms"] for e in ents] == [50.0, 40.0, 30.0]
        assert ents[0]["trace_id"] == "t50"
        assert ents[0]["wait_ms"] == 1.0 and ents[0]["coverage"] == [0, 1]
        assert log.stats() == {"threshold_ms": 10.0, "keep": 3,
                               "kept": 3, "recorded": 5}
        off = SlowQueryLog(threshold_ms=-1.0)
        assert not off.record("/query", 1e9)
        assert off.entries() == []


# ---------------------------------------------------------------------------
# Disabled path: zero samples, zero spans, 404 endpoints
# ---------------------------------------------------------------------------

class TestDisabled:
    def test_null_obs_records_nothing(self):
        obs = NULL_OBS
        assert not obs.enabled and Obs.disabled() is obs
        c = obs.metrics.counter("never")
        assert isinstance(c, NullInstrument)
        c.inc()
        obs.metrics.histogram("h").observe(1.0)
        obs.metrics.gauge("g").set(9.0)
        assert obs.metrics.sample_count() == 0
        assert obs.metrics.expose() == ""
        sp = obs.tracer.start("x")
        assert sp.set("a", 1).error("boom") is sp
        assert sp.header() is None and sp.trace_id == ""
        sp.finish()
        with obs.tracer.span("y") as y:
            assert y.trace_id == ""
        assert len(obs.tracer) == 0
        assert not obs.slow.record("/query", 1e9)

    def test_disabled_registry_is_inert(self):
        reg = Registry(enabled=False)
        reg.histogram("h").observe(5.0)
        reg.register_collector(lambda: [("a", {}, 1)])
        assert reg.sample_count() == 0
        assert reg.expose() == "" and reg.to_dict() == {}

    def test_obs_endpoints_404_without_metrics(self):
        svc = _service().start()
        server = _serve(svc)          # no obs hub → endpoints refuse
        try:
            for p in ("/metrics", "/debug/trace", "/debug/slow"):
                with pytest.raises(urllib.error.HTTPError) as ei:
                    _get_text(f"http://127.0.0.1:{server.port}{p}")
                assert ei.value.code == 404
        finally:
            server.shutdown()
            server.server_close()
            svc.stop()


# ---------------------------------------------------------------------------
# In-process server: header adoption + /metrics + /debug views
# ---------------------------------------------------------------------------

class TestServerObs:
    def test_backend_adopts_trace_header(self):
        obs = Obs.create(service="unit", slow_query_ms=0.0)
        svc = _service(obs=obs).start()
        server = _serve(svc, obs=obs)
        base = f"http://127.0.0.1:{server.port}"
        try:
            out = _post_json(f"{base}/query", {"k": 3},
                             headers={TRACE_HEADER: "aabbccdd/11223344"})
            assert "hits" in out
            # the handler records its span *after* replying — poll for
            # the ring to catch up rather than racing it
            trace_url = f"{base}/debug/trace?trace_id=aabbccdd"
            _wait_for(lambda: _get_json(trace_url)["spans"],
                      timeout=10.0, what="serve/query span in ring")
            spans = _get_json(trace_url)
            (sp,) = [s for s in spans["spans"]
                     if s["name"] == "serve/query"]
            assert sp["parent_id"] == "11223344"
            assert sp["service"] == "unit" and sp["status"] == "ok"
            text = _get_text(f"{base}/metrics")
            assert 'repro_server_request_ms_count{endpoint="/query"' in text
            assert "repro_server_requests_total" in text
            slow = _get_json(f"{base}/debug/slow")
            assert any(e["trace_id"] == "aabbccdd"
                       for e in slow["slowest"])
        finally:
            server.shutdown()
            server.server_close()
            svc.stop()


# ---------------------------------------------------------------------------
# Live plane: one trace id across router + two replica processes,
# with an injected retry and a degraded drop along the way
# ---------------------------------------------------------------------------

class TestLiveTracePropagation:
    def test_trace_stitches_across_processes(self, tmp_path):
        """Boot a real 2-shard × 1-replica plane with --metrics and a
        fault plan that (a) drops replica-0.0's next two requests — the
        router must retry and succeed — and (b) delays replica-1.0's
        next request past the router budget — shard 1 must degrade.
        One trace id must stitch the whole story across ≥3 processes.

        Request-counter arithmetic: the launcher's single boot-time
        ``router.health()`` is request #1 at every backend, so ``at=2``
        aims both faults at the test's one query (readiness is polled
        via GET /metrics, which is router-local and does not fan out).
        """
        plan = FaultPlan.build(
            FaultPlan.drop_requests("replica", 0, at=2, every=1, count=2),
            FaultPlan.slow_requests("replica", 1, at=2, delay_s=5.0),
            seed=11)
        plan_file = tmp_path / "plan.json"
        plan_file.write_text(plan.to_json())
        port_file = tmp_path / "router.port"
        cmd = [sys.executable, "-m", "repro.launch.cluster_serve",
               "--dataset", "random", "--n-tuples", "2000",
               "--shards", "2", "--replicas", "1",
               "--metrics", "--slow-query-ms", "0",
               "--no-supervise", "--router-timeout", "2",
               "--port", "0", "--port-file", str(port_file),
               "--fault-plan", str(plan_file)]
        proc = subprocess.Popen(cmd, env=_env(), text=True,
                                stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT)
        lines = []
        pump = threading.Thread(
            target=lambda: lines.extend(proc.stdout),  # type: ignore
            daemon=True)
        pump.start()

        def backend_ports():
            # the topology's port files live in a subprocess-private
            # tmp dir; children announce their ports on stdout instead.
            # children share one pipe, so two announcements can land on
            # one line — match every [tag]...port=N pair, never letting
            # a greedy wildcard cross into the next announcement
            ports = {}
            for ln in list(lines):
                for m in re.finditer(r"\[(replica-\d+\.\d+|shard-\d+)\]"
                                     r"[^\[]*port=(\d+)", ln):
                    ports[m.group(1)] = int(m.group(2))
            return ports

        try:
            _wait_for(lambda: port_file.exists()
                      and port_file.read_text().strip(),
                      timeout=120, what="router port file")
            base = f"http://127.0.0.1:{int(port_file.read_text())}"

            def router_up():
                try:
                    return bool(_get_text(f"{base}/metrics", timeout=2.0))
                except OSError:
                    return False
            _wait_for(router_up, timeout=60, what="router /metrics")
            _wait_for(lambda: {"replica-0.0", "replica-1.0"}
                      <= set(backend_ports()),
                      timeout=60, what="replica port announcements")
            ports = backend_ports()

            # -- the one query: shard 0 retries, shard 1 degrades ------
            out = _post_json(f"{base}/query", {"k": 5}, timeout=30)
            assert out["degraded"] is True
            assert out["coverage"] == [0]
            tid = out["trace_id"]
            assert re.fullmatch(r"[0-9a-f]{16}", tid)

            # the router records root span → request metrics → slow-log
            # entry *after* replying; the slow entry is last, so its
            # arrival means every router-side record is in place
            _wait_for(lambda: any(e.get("trace_id") == tid for e in
                                  _get_json(f"{base}/debug/slow")
                                  ["slowest"]),
                      timeout=30, what="router slow-log entry")

            # -- router-side spans -------------------------------------
            doc = _get_json(f"{base}/debug/trace?trace_id={tid}")
            rspans = doc["spans"]
            by_name = {}
            for s in rspans:
                by_name.setdefault(s["name"], []).append(s)
            (root,) = by_name["router/query"]
            assert root["parent_id"] is None
            shard_sp = {s["attrs"]["shard"]: s
                        for s in by_name["router.shard"]}
            assert set(shard_sp) == {0, 1}
            assert all(s["parent_id"] == root["span_id"]
                       for s in shard_sp.values())
            attempts = by_name["router.attempt"]
            assert all(a["parent_id"] == shard_sp[a["attrs"]["shard"]]
                       ["span_id"] for a in attempts)
            s0 = [a["attrs"]["outcome"] for a in attempts
                  if a["attrs"]["shard"] == 0]
            assert "retry" in s0 and s0[-1] == "ok"    # injected retry
            s1 = [a["attrs"]["outcome"] for a in attempts
                  if a["attrs"]["shard"] == 1]
            assert "ok" not in s1                      # budget blown
            (drop,) = by_name["router.degraded_drop"]
            assert drop["attrs"]["shard"] == 1
            assert drop["status"] == "error"
            assert drop["parent_id"] == root["span_id"]

            # -- backend spans: same trace id, distinct pids -----------
            attempt_ids = {a["span_id"] for a in attempts}

            def replica_spans(name):
                url = (f"http://127.0.0.1:{ports[name]}"
                       f"/debug/trace?trace_id={tid}")
                try:
                    return [s for s in _get_json(url)["spans"]
                            if s["name"] == "serve/query"]
                except OSError:
                    return []

            _wait_for(lambda: replica_spans("replica-0.0"),
                      timeout=30, what="replica-0.0 serve/query span")
            # replica-1.0's handler only finishes after the injected 5 s
            # delay — well after the router already returned degraded
            _wait_for(lambda: replica_spans("replica-1.0"),
                      timeout=30, what="replica-1.0 serve/query span")
            r0 = replica_spans("replica-0.0")
            r1 = replica_spans("replica-1.0")
            assert all(s["parent_id"] in attempt_ids for s in r0 + r1)
            pids = ({s["pid"] for s in rspans}
                    | {s["pid"] for s in r0 + r1})
            assert len(pids) >= 3       # router + both replica procs

            # -- slow log + always-on endpoint latency -----------------
            slow = _get_json(f"{base}/debug/slow")
            (ent,) = [e for e in slow["slowest"]
                      if e.get("trace_id") == tid]
            assert ent["endpoint"] == "/query"
            assert ent["handler_ms"] is not None
            assert ent["wait_ms"] is not None
            assert ent["coverage"] == [0]
            text = _get_text(f"{base}/metrics")
            assert ('repro_router_endpoint_latency_ms_count'
                    '{endpoint="/query"} 1.0') in text
            assert 'repro_router_request_ms_count{endpoint="/query"} 1' \
                in text
            assert "repro_router_breaker_open" in text

            try:
                _post_json(f"{base}/shutdown", {}, timeout=10)
            except OSError:
                pass
            proc.wait(timeout=90)
        finally:
            if proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=15)
                except subprocess.TimeoutExpired:
                    proc.kill()
