"""Batch JAX engine vs. the pure-python reference oracles."""
import numpy as np
import pytest

from repro.core import BatchMiner, PolyadicContext, tricontext
from repro.core import reference as ref
from repro.core.postprocess import cluster_set
from repro.data import synthetic


def _oracle_clusters(ctx, theta=0.0):
    _, _, _, kept = ref.multimodal_clusters(ctx, theta=theta)
    return {tuple(tuple(sorted(c)) for c in cl) for cl in kept}


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("sizes,t", [((6, 5, 4), 40), ((8, 8, 8), 120),
                                     ((4, 3, 5, 3), 60)])
def test_batch_matches_oracle_random(sizes, t, seed):
    ctx = synthetic.random_context(sizes, t, seed=seed)
    miner = BatchMiner(sizes)
    got = cluster_set(miner.mine_context(ctx))
    want = _oracle_clusters(ctx)
    assert got == want


def test_batch_matches_online_oac_prime():
    """Triadic case: unique clusters == the online Alg. 1's unique set."""
    ctx = synthetic.random_context((7, 6, 5), 80, seed=3)
    algo = ref.online_oac_prime(ctx)
    want = {tuple(tuple(sorted(c)) for c in t) for t in algo.unique()}
    miner = BatchMiner(ctx.sizes)
    got = cluster_set(miner.mine_context(ctx))
    assert got == want


def test_duplicate_idempotence():
    """M/R at-least-once semantics: duplicated tuples change nothing."""
    ctx = synthetic.random_context((6, 6, 6), 50, seed=4)
    dup = PolyadicContext(ctx.sizes,
                          np.concatenate([ctx.tuples, ctx.tuples[::2]]))
    m = BatchMiner(ctx.sizes)
    assert cluster_set(m.mine_context(ctx)) == cluster_set(m.mine_context(dup))
    # densities must also be unaffected (distinct generating tuples)
    a = dict(((tuple(tuple(sorted(c)) for c in comps)), d)
             for comps, d in m.mine_context(ctx))
    b = dict(((tuple(tuple(sorted(c)) for c in comps)), d)
             for comps, d in m.mine_context(dup))
    assert a == b


def test_density_theta_filter():
    ctx = synthetic.random_context((5, 5, 5), 60, seed=5)
    _, _, density, kept = ref.multimodal_clusters(ctx, theta=0.5)
    got = cluster_set(BatchMiner(ctx.sizes, theta=0.5).mine_context(ctx))
    want = {tuple(tuple(sorted(c)) for c in cl) for cl in kept}
    assert got == want


def test_density_values_match_alg7():
    """Per-cluster density equals the Alg. 7 estimate exactly."""
    ctx = synthetic.random_context((6, 5, 4), 70, seed=6)
    _, _, density, _ = ref.multimodal_clusters(ctx)
    m = BatchMiner(ctx.sizes)
    for comps, d in m.mine_context(ctx):
        key = tuple(tuple(sorted(c)) for c in comps)
        assert key in density
        assert d == pytest.approx(density[key], rel=1e-6)


def test_k3_single_cluster():
    """Paper §5.1: K3 must assemble exactly one cluster (A1,A2,A3,A4)."""
    ctx = synthetic.k3_dense_4d(n=5)
    m = BatchMiner(ctx.sizes)
    res = m.mine_context(ctx)
    assert len(res) == 1
    comps, d = res[0]
    assert all(c == frozenset(range(5)) for c in comps)
    assert d == pytest.approx(1.0)


def test_k1_diagonal_holes():
    """K1 (dense minus diagonal): every cluster's density is < 1 but high."""
    ctx = synthetic.k1_dense_cube(n=6)
    m = BatchMiner(ctx.sizes)
    out = m.mine_context(ctx)
    assert out, "K1 must produce clusters"
    want = _oracle_clusters(ctx)
    assert cluster_set(out) == want


def test_k2_three_clusters():
    ctx = synthetic.k2_three_cuboids(n=4)
    out = BatchMiner(ctx.sizes).mine_context(ctx)
    assert len(out) == 3
    for comps, d in out:
        assert d == pytest.approx(1.0)


def test_exact_density_dense_backend():
    """Beyond-paper exact density path equals the numpy oracle."""
    import jax.numpy as jnp
    from repro.core.batch import dense_tensor, fibers, exact_density_dense
    ctx = synthetic.random_context((6, 5, 4), 50, seed=7)
    tens = dense_tensor(jnp.asarray(ctx.tuples), ctx.sizes)
    masks = fibers(tens, jnp.asarray(ctx.tuples))
    dens = np.asarray(exact_density_dense(tens, masks))
    _, uniq, _, _ = ref.multimodal_clusters(ctx)
    for i, row in enumerate(map(tuple, ctx.tuples.tolist())):
        cluster = tuple(
            ref.cumulus(ctx, row, k) for k in range(3))
        want = ref.exact_density(ctx, cluster)
        assert dens[i] == pytest.approx(want, rel=1e-5)
