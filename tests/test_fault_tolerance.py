"""Supervisor: crash-restart, straggler detection, restart budget."""
import os
import sys
import textwrap

from repro.train.fault_tolerance import Supervisor, beat, last_beat


def _script(tmp_path, body: str) -> list:
    path = os.path.join(str(tmp_path), "worker.py")
    with open(path, "w") as f:
        f.write(textwrap.dedent(body))
    return [sys.executable, path]


def test_crash_then_success(tmp_path):
    """First run crashes; the supervisor restarts; second run succeeds."""
    marker = os.path.join(str(tmp_path), "ran_once")
    hb = os.path.join(str(tmp_path), "hb")
    argv = _script(tmp_path, f"""
        import os, sys, time
        hb = {hb!r}; marker = {marker!r}
        for step in range(5):
            open(hb, "w").write(str(step))
            time.sleep(0.05)
        if not os.path.exists(marker):
            open(marker, "w").write("x")
            os._exit(13)          # injected crash on the first attempt
        sys.exit(0)
    """)
    sup = Supervisor(argv, heartbeat=hb, heartbeat_timeout=30,
                     max_restarts=2, poll_interval=0.05)
    assert sup.run() == 0


def test_straggler_killed_and_restarted(tmp_path):
    """A worker that stops heartbeating is killed and re-run."""
    marker = os.path.join(str(tmp_path), "hung_once")
    hb = os.path.join(str(tmp_path), "hb")
    argv = _script(tmp_path, f"""
        import os, sys, time
        hb = {hb!r}; marker = {marker!r}
        open(hb, "w").write("0")
        if not os.path.exists(marker):
            open(marker, "w").write("x")
            time.sleep(3600)      # simulated hang (no more heartbeats)
        for step in range(3):
            open(hb, "w").write(str(step))
            time.sleep(0.05)
        sys.exit(0)
    """)
    sup = Supervisor(argv, heartbeat=hb, heartbeat_timeout=1.0,
                     max_restarts=2, grace_period=5.0, poll_interval=0.1)
    assert sup.run() == 0


def test_restart_budget_exhausted(tmp_path):
    hb = os.path.join(str(tmp_path), "hb")
    argv = _script(tmp_path, """
        import os
        os._exit(7)
    """)
    sup = Supervisor(argv, heartbeat=hb, heartbeat_timeout=5,
                     max_restarts=1, poll_interval=0.05)
    assert sup.run() != 0


def test_beat_helpers(tmp_path):
    hb = os.path.join(str(tmp_path), "hb")
    assert last_beat(hb) is None
    beat(hb, 3)
    assert last_beat(hb) is not None
