"""CLI driver smoke tests: tricluster / train / serve mains."""
import json
import os

import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod
from repro.launch import tricluster as tri_mod


def test_tricluster_batch_imdb(capsys):
    assert tri_mod.main(["--dataset", "imdb", "--backend", "batch",
                         "--print-top", "1"]) == 0
    out = capsys.readouterr().out
    assert "unique clusters" in out


def test_tricluster_reference_and_noac(capsys):
    assert tri_mod.main(["--dataset", "random", "--n-tuples", "256",
                         "--backend", "reference"]) == 0
    assert tri_mod.main(["--dataset", "frames", "--n-tuples", "512",
                         "--delta", "100", "--rho-min", "0.5"]) == 0
    out = capsys.readouterr().out
    assert "NOAC" in out


def test_tricluster_streaming(capsys):
    assert tri_mod.main(["--dataset", "random", "--n-tuples", "512",
                         "--backend", "streaming", "--chunks", "4"]) == 0


def test_train_driver_with_resume(tmp_path, capsys):
    ckpt = str(tmp_path / "ckpt")
    metrics = str(tmp_path / "m.json")
    args = ["--arch", "h2o-danube-1.8b", "--smoke", "--steps", "6",
            "--global-batch", "2", "--seq", "32", "--ckpt-dir", ckpt,
            "--ckpt-every", "3", "--log-every", "2",
            "--metrics-out", metrics]
    assert train_mod.main(args) == 0
    rows = json.load(open(metrics))
    assert rows[-1]["step"] == 6
    # resume two more steps from the checkpoint
    args2 = [a if a != "6" else "8" for a in args] + ["--resume", "auto"]
    assert train_mod.main(args2) == 0
    out = capsys.readouterr().out
    assert "resumed from step" in out


def test_serve_driver(capsys):
    assert serve_mod.main(["--arch", "qwen3-0.6b", "--smoke",
                           "--batch", "2", "--prompt-len", "8",
                           "--new-tokens", "4", "--max-len", "32"]) == 0
    out = capsys.readouterr().out
    assert "tok/s" in out
