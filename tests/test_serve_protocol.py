"""HTTP endpoint + client (serve/protocol.py): full round-trip against
an in-process server on an ephemeral port."""
import threading

import numpy as np
import pytest

from repro.data import synthetic
from repro.serve.protocol import ClusterClient, make_server
from repro.serve.service import TriclusterService


@pytest.fixture(scope="module")
def served():
    ctx = synthetic.random_context((8, 7, 6), 96, seed=7)
    svc = TriclusterService(ctx.sizes, refresh_interval=0.01,
                            dirty_threshold=1)
    svc.add(ctx.tuples)
    svc.start()
    server = make_server(svc, port=0)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    client = ClusterClient(f"http://127.0.0.1:{server.port}")
    yield ctx, svc, client
    server.shutdown()
    server.server_close()
    svc.stop()


def test_health_and_stats(served):
    ctx, svc, cl = served
    h = cl.health()
    assert h["version"] >= 1 and h["clusters"] == len(svc.snapshot().index)
    st = cl.stats()
    assert st["sizes"] == list(ctx.sizes) and st["publishes"] >= 1


def test_scalar_query_matches_service(served):
    ctx, svc, cl = served
    e = int(ctx.tuples[0, 1])
    got = cl.query(entity=e, mode=1, k=5, include_components=True)
    want = svc.query(entity=e, mode=1, k=5)
    if got["version"] == want.version:
        assert [tuple(h["signature"]) for h in got["hits"]] \
            == [v.signature for v, _ in want.hits]
        assert [sorted(c) for c in want.hits[0][0].components] \
            == got["hits"][0]["components"]


def test_batch_and_signature_query(served):
    ctx, svc, cl = served
    ents = list(range(8))
    got = cl.query_batch(ents, mode=0, k=3)
    assert len(got["hits"]) == len(ents)
    scalar = cl.query(entity=ents[0], mode=0, k=3)
    if scalar["version"] == got["version"]:
        assert got["hits"][0] == scalar["hits"]
    top = cl.query(k=1)
    sig = top["hits"][0]["signature"]
    by_sig = cl.query(signature=sig)
    assert [h["signature"] for h in by_sig["hits"]] == [sig]
    assert cl.query(signature=[0, 0])["hits"] == []


def test_write_refresh_freshness(served):
    ctx, svc, cl = served
    v0 = cl.health()["version"]
    up = cl.upsert(ctx.tuples[:2].tolist())
    assert up["stream_version"] == svc.stream_version
    ref = cl.refresh()
    assert ref["version"] > v0
    fresh = cl.query(entity=0, at_least_version=ref["version"], timeout=30)
    assert fresh["version"] >= ref["version"]
    d = cl.delete(ctx.tuples[:1].tolist())
    assert d["stream_version"] > up["stream_version"]


def test_errors(served):
    _, _, cl = served
    with pytest.raises(RuntimeError, match="out of range"):
        cl.query(entity=0, mode=9)
    with pytest.raises(RuntimeError, match="rows"):
        cl.upsert([])
    with pytest.raises(RuntimeError, match="not published"):
        # unreachable freshness: surfaces as 504 -> RuntimeError... use
        # a version far ahead with tiny timeout
        cl.query(entity=0, at_least_version=10_000, timeout=0.05)
