"""Fault-tolerant serving plane (ISSUE 7) and data-integrity plane
(ISSUE 8): deterministic fault injection, degraded router fan-out,
circuit-break + re-probe, crash-safe shm recovery, checkpoint+WAL writer
recovery with CRC verification, quarantine + generation fallback, the
background scrubber, write backpressure, and process supervision.

Every fault here triggers on a logical counter (seeded ``FaultPlan``),
and every assertion synchronises on an observable state transition with
a bounded wait — never on a bare sleep.
"""
import glob
import json
import os
import subprocess
import sys
import threading
import time
import types

import numpy as np
import pytest

from repro.core import StreamingMiner
from repro.serve.clusters import ClusterIndex
from repro.serve.faults import (KILL_EXIT_CODE, DropRequest, Fault,
                                FaultInjector, FaultPlan)
from repro.serve.protocol import ClusterClient, health_doc, make_server
from repro.serve.router import RouterService, Shard
from repro.serve.service import TriclusterService
from repro.serve.supervise import Supervisor, write_restart_flag

SIZES = (24, 12, 8)


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    return env


def _wait_for(cond, timeout=30.0, what="condition"):
    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() >= deadline:
            raise TimeoutError(f"{what} not reached in {timeout}s")
        time.sleep(0.01)


def _service(seed=3, n=160, **kw):
    rng = np.random.default_rng(seed)
    svc = TriclusterService(SIZES, refresh_interval=0.05,
                            dirty_threshold=4, seed=seed, **kw)
    svc.add(rng.integers(0, SIZES, size=(n, 3)).astype(np.int64))
    return svc


def _serve(svc, fault=None, health_max_staleness=None):
    server = make_server(svc, port=0, fault=fault,
                         health_max_staleness=health_max_staleness)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    return server


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_json_round_trip_and_scoping(self):
        plan = FaultPlan.build(
            FaultPlan.kill_writer(1, 7),
            FaultPlan.hang_replica(0, 2, 5, for_s=0.5),
            FaultPlan.drop_requests("replica", -1, at=3),
            seed=42)
        again = FaultPlan.from_json(plan.to_json())
        assert again == plan
        # scoping: the writer fault only reaches writer shard 1
        assert len(plan.for_component("writer", 1).faults) == 1
        assert len(plan.for_component("writer", 0).faults) == 0
        # replica faults: the wildcard drop hits every replica; the
        # hang only (0, 2)
        assert len(plan.for_component("replica", 0, 2).faults) == 2
        assert len(plan.for_component("replica", 1, 0).faults) == 1

    def test_scattered_is_seed_deterministic(self):
        a = FaultPlan.scattered(7, "replica", 0, window=100,
                                n_drop=3, n_slow=2)
        b = FaultPlan.scattered(7, "replica", 0, window=100,
                                n_drop=3, n_slow=2)
        c = FaultPlan.scattered(8, "replica", 0, window=100,
                                n_drop=3, n_slow=2)
        assert a == b
        assert a != c
        ats = [f.at for f in a.faults]
        assert len(set(ats)) == 5 and all(1 <= o <= 100 for o in ats)

    def test_counter_trigger_once_and_every(self):
        inj = FaultInjector([
            Fault("drop", "request", at=3),
            Fault("drop", "request", at=10, every=5, count=2)])
        fired = []
        for i in range(1, 21):
            try:
                inj.fire("request", i)
            except DropRequest:
                fired.append(i)
        assert fired == [3, 10, 15]          # once at 3; 10,15 then
        assert inj.fired("request") == 3     # count=2 exhausted

    def test_clear_disarms(self):
        inj = FaultInjector([Fault("drop", "request", at=1, every=1,
                                   count=0)])
        with pytest.raises(DropRequest):
            inj.fire("request", 1)
        inj.clear("request")
        inj.fire("request", 2)               # no raise

    def test_corruption_faults_round_trip_and_poll(self):
        plan = FaultPlan.build(
            FaultPlan.flip_wal_byte(0, at_stream_version=3),
            FaultPlan.truncate_checkpoint(1, at_version=2),
            FaultPlan.flip_shm_word(0, at_version=4))
        assert FaultPlan.from_json(plan.to_json()) == plan
        inj = plan.for_component("writer", 0)
        assert len(inj.faults) == 2
        # fire() never enacts corruption kinds — the owning call site
        # polls corrupt() and rots its own bytes after the checksum
        inj.fire("wal", 3)                   # no raise, no consumption
        f = inj.corrupt("wal", 3)
        assert f is not None and f.kind == "flip"
        assert inj.corrupt("wal", 4) is None          # count=1 spent
        assert inj.corrupt("shm", 4).kind == "flip"
        assert inj.corrupt("checkpoint", 9) is None   # scoped to shard 1


# ---------------------------------------------------------------------------
# /health 503 + drain (satellites)
# ---------------------------------------------------------------------------

class _StubService:
    """Service-shaped object with scriptable health inputs."""
    read_only = True
    version = 3
    stream_version = 5
    dirty = 2
    dirty_clusters = 0
    _snap = None

    def __init__(self):
        self.thread_alive = True
        self.stale = 0.1
        self.block = None

    def staleness_s(self):
        return self.stale

    def stats(self):
        return {"role": "stub"}

    def query(self, **kw):
        if self.block is not None:
            self.block.wait(10)
        from repro.serve.service import QueryResult
        return QueryResult(self.version, self.stream_version, [])


class TestHealth503AndDrain:
    def test_health_503_on_dead_thread_and_staleness(self):
        svc = _StubService()
        server = _serve(svc, health_max_staleness=5.0)
        try:
            cl = ClusterClient(f"http://127.0.0.1:{server.port}",
                               timeout=10)
            h = cl.health()
            assert h["healthy"] and "http_status" not in h
            # staleness past the threshold with a write backlog: sick
            svc.stale = 60.0
            h = cl.health()
            assert h["http_status"] == 503 and not h["healthy"]
            assert "stale" in h["error"]
            # dead background thread: sick regardless of staleness
            svc.stale = 0.1
            svc.thread_alive = False
            h = cl.health()
            assert h["http_status"] == 503
            assert "thread" in h["error"]
        finally:
            server.shutdown()
            server.server_close()

    def test_health_doc_thresholds(self):
        svc = _StubService()
        assert health_doc(svc)["healthy"]
        svc.stale = 99.0
        assert health_doc(svc)["healthy"]          # no threshold set
        assert not health_doc(svc, max_staleness_s=1.0)["healthy"]
        svc.dirty = 0                              # drained: stale is
        assert health_doc(svc, max_staleness_s=1.0)["healthy"]  # fine

    def test_drain_waits_for_inflight(self):
        svc = _StubService()
        svc.block = threading.Event()
        server = _serve(svc)
        cl = ClusterClient(f"http://127.0.0.1:{server.port}", timeout=30)
        res = {}
        t = threading.Thread(
            target=lambda: res.update(cl.query(entity=0)), daemon=True)
        t.start()
        _wait_for(lambda: server.inflight == 1, what="in-flight request")
        server.shutdown()                    # stop accepting
        assert not server.drain_inflight(timeout=0.2)   # still held
        svc.block.set()
        assert server.drain_inflight(timeout=10)
        t.join(timeout=10)
        assert res["version"] == 3
        server.server_close()

    def test_injected_drop_severs_connection(self):
        svc = _StubService()
        inj = FaultPlan.build(
            FaultPlan.drop_requests("replica", -1, at=2)
        ).for_component("replica", 0)
        server = _serve(svc, fault=inj)
        try:
            cl = ClusterClient(f"http://127.0.0.1:{server.port}",
                               timeout=5)
            assert cl.health()["version"] == 3       # request 1 fine
            with pytest.raises(OSError):
                cl.health()                          # request 2 severed
            assert cl.health()["version"] == 3       # request 3 fine
        finally:
            server.shutdown()
            server.server_close()


# ---------------------------------------------------------------------------
# Router: shard crash → degraded merge; replica hang → breaker + re-probe
# ---------------------------------------------------------------------------

class TestRouterDegradation:
    def _plane(self, **router_kw):
        svcs = [_service(seed=s).start() for s in (3, 4)]
        servers = [_serve(s) for s in svcs]
        shards = [Shard(f"http://127.0.0.1:{sv.port}", timeout=2.0)
                  for sv in servers]
        router = RouterService(shards, timeout=2.0, **router_kw)
        return svcs, servers, router

    def test_shard_down_degrades_instead_of_502(self):
        svcs, servers, router = self._plane()
        try:
            full = router.query(k=5)
            assert not full["degraded"] and full["coverage"] == [0, 1]
            # kill shard 1's endpoint entirely
            servers[1].shutdown()
            servers[1].server_close()
            deg = router.query(k=5, timeout=1.0)
            assert deg["degraded"] and deg["coverage"] == [0]
            assert deg["shard_versions"][1] == 0
            # the degraded merge is exactly the live shard's ranked list
            local = [(int(v.signature[0]), int(v.signature[1]))
                     for v, _ in svcs[0].query(k=5).hits]
            assert [tuple(h["signature"]) for h in deg["hits"]] == local
            # batch degrades the same way
            degb = router.query_batch([0, 1], k=3, timeout=1.0)
            assert degb["degraded"] and len(degb["hits"]) == 2
            # all-or-nothing stays available
            with pytest.raises((RuntimeError, OSError, TimeoutError)):
                router.query(k=5, timeout=1.0, require_all=True)
            # tolerant health: the down endpoint is reported, not fatal
            h = router.health()
            assert h["degraded"] and len(h["down"]) == 1
            assert h["coverage"] == [0]
        finally:
            router.close()
            for sv in servers:
                sv.shutdown()
                sv.server_close()
            for s in svcs:
                s.stop()

    def test_every_shard_down_is_an_error(self):
        svcs, servers, router = self._plane()
        try:
            for sv in servers:
                sv.shutdown()
                sv.server_close()
            with pytest.raises(RuntimeError, match="unreachable"):
                router.query(k=3, timeout=0.5)
        finally:
            router.close()
            for s in svcs:
                s.stop()

    def test_hung_replica_circuit_breaks_then_reprobes(self):
        svc = _service(seed=5).start()
        writer_srv = _serve(svc)
        # the "replica": same service behind a faulted endpoint that
        # hangs its first 3 requests longer than the client timeout
        plan = FaultPlan.build(
            Fault("hang", "request", role="replica", at=1, every=1,
                  count=3, param=5.0))
        hang_inj = plan.for_component("replica", 0, 0)
        replica_srv = _serve(svc, fault=hang_inj)
        sh = Shard(f"http://127.0.0.1:{writer_srv.port}",
                   [f"http://127.0.0.1:{replica_srv.port}"], timeout=0.4)
        router = RouterService([sh], timeout=3.0, probe_interval=0.05,
                               probe_timeout=0.4)
        try:
            replica = sh.replicas[0]
            # queries keep succeeding end-to-end: retries time out on
            # the hung replica, the breaker opens, traffic fails over
            # to the writer — no 5xx, no degradation
            out = router.query(k=3)
            assert not out["degraded"]
            _wait_for(lambda: replica.breaker.is_open, timeout=15,
                      what="replica circuit open")
            assert sh.reader() is sh.writer  # ejected → writer serves
            # hang budget (count=3) exhausts via query retries and the
            # background /health re-probe; the breaker must close again
            # without any query traffic forcing it
            _wait_for(lambda: not replica.breaker.is_open, timeout=30,
                      what="replica circuit re-closed")
            stats = router.resilience_stats()
            assert stats["probes"] >= 1
            assert any(b["trips"] >= 1 for b in stats["breakers"])
            out = router.query(k=3)
            assert not out["degraded"] and out["coverage"] == [0]
        finally:
            router.close()
            for sv in (writer_srv, replica_srv):
                sv.shutdown()
                sv.server_close()
            svc.stop()

    def test_stale_keepalive_retries_once_on_fresh_connection(self):
        """PooledClient satellite: a backend restart between requests
        leaves a dead keep-alive socket; the next call must transparently
        reconnect instead of failing."""
        svc = _service(seed=6).start()
        server = _serve(svc)
        port = server.port
        sh = Shard(f"http://127.0.0.1:{port}", timeout=5.0)
        try:
            assert sh.writer.call("/health")["version"] >= 1
            server.shutdown()
            server.server_close()            # keep-alive now stale
            server = make_server(svc, port=port)   # same port, new srv
            threading.Thread(target=server.serve_forever,
                             daemon=True).start()
            assert sh.writer.call("/health")["version"] >= 1
            assert not sh.writer.breaker.is_open
        finally:
            server.shutdown()
            server.server_close()
            svc.stop()


# ---------------------------------------------------------------------------
# Writer crash → checkpoint + WAL recovery (monotone stream_version,
# bit-identical answers)
# ---------------------------------------------------------------------------

def _top_sigs(svc, k=8):
    out = svc.query(k=k)
    return [(int(v.signature[0]), int(v.signature[1]),
             round(float(s), 12)) for v, s in out.hits]


class TestWriterRecovery:
    def test_checkpoint_wal_replay_bit_identical(self, tmp_path):
        rec = str(tmp_path / "rec")
        os.makedirs(rec)
        rng = np.random.default_rng(11)
        base = rng.integers(0, SIZES, size=(150, 3)).astype(np.int64)
        extra = rng.integers(0, SIZES, size=(5, 4, 3)).astype(np.int64)

        # uninterrupted control
        ctl = TriclusterService(SIZES, seed=11)
        ctl.add(base)
        for chunk in extra:
            ctl.add(chunk)
        ctl.refresh()

        # victim: checkpoint after every write, then "crash" (drop the
        # instance with no stop/final_checkpoint)
        vic = TriclusterService(SIZES, seed=11, recover_dir=rec,
                                checkpoint_every=3)
        vic.add(base)
        vic.refresh()
        v_before = vic.version
        for chunk in extra[:3]:
            vic.add(chunk)
        vic.refresh()                        # cadence checkpoint ran
        sv_crash = vic.stream_version
        assert vic.stats()["checkpoints"] >= 1
        del vic                              # crash: no graceful stop

        successor = TriclusterService(SIZES, seed=11, recover_dir=rec,
                                      checkpoint_every=3)
        r = successor.recovered
        assert r["stream_version"] == sv_crash          # monotone
        assert successor.stream_version == sv_crash
        for chunk in extra[3:]:
            successor.add(chunk)
        successor.refresh()
        assert successor.version > v_before             # version floor
        assert successor.stream_version == ctl.stream_version
        assert _top_sigs(successor) == _top_sigs(ctl)   # bit-identical
        ctl.stop()
        successor.stop()

    def test_wal_alone_recovers_without_checkpoint(self, tmp_path):
        rec = str(tmp_path / "rec2")
        os.makedirs(rec)
        rng = np.random.default_rng(13)
        rows = rng.integers(0, SIZES, size=(60, 3)).astype(np.int64)
        vic = TriclusterService(SIZES, seed=13, recover_dir=rec,
                                checkpoint_every=10**6)
        vic.add(rows[:40])
        vic.upsert(rows[40:55])
        vic.delete(rows[:5])
        sv = vic.stream_version
        del vic                              # crash before any ckpt

        ctl = TriclusterService(SIZES, seed=13)
        ctl.add(rows[:40])
        ctl.upsert(rows[40:55])
        ctl.delete(rows[:5])

        successor = TriclusterService(SIZES, seed=13, recover_dir=rec)
        assert successor.recovered["replayed_ops"] == 3
        assert successor.stream_version == sv == ctl.stream_version
        successor.refresh()
        ctl.refresh()
        assert _top_sigs(successor) == _top_sigs(ctl)
        ctl.stop()
        successor.stop()

    def test_injected_kill_at_stream_version(self, tmp_path):
        """The kill-shard-at-version-N fault: a child process dies with
        KILL_EXIT_CODE exactly after its N-th write lands in the WAL;
        its successor recovers every logged op."""
        rec = str(tmp_path / "reckill")
        os.makedirs(rec)
        child = f"""
import sys, numpy as np
sys.path.insert(0, "src")
from repro.serve.faults import FaultPlan
from repro.serve.service import TriclusterService
plan = FaultPlan.build(FaultPlan.kill_writer(0, at_stream_version=3))
svc = TriclusterService({SIZES!r}, seed=2, recover_dir={rec!r},
                        fault=plan.for_component("writer", 0))
rng = np.random.default_rng(2)
for i in range(5):
    svc.add(rng.integers(0, {SIZES!r}, size=(4, 3)).astype(np.int64))
raise SystemExit("unreachable: kill fault must fire at sv=3")
"""
        proc = subprocess.run([sys.executable, "-c", child],
                              cwd=os.getcwd(), env=_env(), timeout=300,
                              capture_output=True, text=True)
        assert proc.returncode == KILL_EXIT_CODE, proc.stderr
        successor = TriclusterService(SIZES, seed=2, recover_dir=rec)
        # the fault fires *after* write 3 commits: all 3 ops recovered
        assert successor.stream_version == 3
        assert successor.recovered["replayed_ops"] == 3
        successor.stop()


# ---------------------------------------------------------------------------
# Supervision: restart on crash, crash-loop cap, restart flags
# ---------------------------------------------------------------------------

class _Popen:
    """multiprocessing-Process-shaped adapter over subprocess.Popen —
    keeps supervisor tests free of spawn-import pickling concerns."""

    def __init__(self, argv):
        self._p = subprocess.Popen(argv, env=_env(),
                                   stdout=subprocess.DEVNULL,
                                   stderr=subprocess.DEVNULL)

    @property
    def pid(self):
        return self._p.pid

    @property
    def exitcode(self):
        return self._p.returncode

    def is_alive(self):
        return self._p.poll() is None

    def terminate(self):
        self._p.terminate()

    def join(self, timeout=None):
        try:
            self._p.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            pass


def _sleeper():
    return _Popen([sys.executable, "-c",
                   "import time; time.sleep(600)"])


def _crasher():
    return _Popen([sys.executable, "-c", "import sys; sys.exit(23)"])


class TestSupervisor:
    def test_restart_then_crash_loop_failure(self):
        sup = Supervisor(restart_backoff=0.02, backoff_max=0.1,
                         max_restarts=3, restart_window=60.0,
                         poll_interval=0.02)
        sup.add("loop", _crasher)
        sup.add("ok", _sleeper)
        with sup:
            assert sup.wait_state("loop", ("failed",),
                                  timeout=30) == "failed"
            st = sup.stats()["children"]
            assert st["loop"]["restarts"] >= 3
            assert st["loop"]["last_exit"] == 23
            assert st["ok"]["state"] == "running" and st["ok"]["alive"]
        events = [e for n, e, _ in sup.events if n == "loop"]
        assert events.count("restarting") >= 3
        assert events[-1] == "failed"

    def test_clean_exit_is_not_restarted(self):
        sup = Supervisor(poll_interval=0.02)
        sup.add("oneshot",
                lambda: _Popen([sys.executable, "-c", "pass"]))
        with sup:
            assert sup.wait_state("oneshot", ("stopped",),
                                  timeout=30) == "stopped"
        assert sup.stats()["children"]["oneshot"]["restarts"] == 0

    def test_restart_flag_recycles_hung_child(self, tmp_path):
        flag_dir = str(tmp_path)
        sup = Supervisor(restart_backoff=0.02, poll_interval=0.02,
                         flag_dir=flag_dir)
        sup.add("writer", _sleeper)
        with sup:
            pid0 = sup.stats()["children"]["writer"]["pid"]
            write_restart_flag(flag_dir, "writer")
            _wait_for(lambda: (sup.stats()["children"]["writer"]
                               ["restarts"]) == 1, timeout=30,
                      what="flagged restart")
            sup.wait_state("writer", ("running",), timeout=30)
            st = sup.stats()["children"]["writer"]
            assert st["alive"] and st["pid"] != pid0
            assert not os.path.exists(
                os.path.join(flag_dir, "writer.restart"))
        assert ("writer", "flagged", "restart flag") in sup.events


# ---------------------------------------------------------------------------
# Crash-safe shm: torn publish → stuck-odd → adopt + GC + epoch republish
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not os.path.isdir("/dev/shm"),
                    reason="POSIX shm namespace required")
class TestShmCrashSafety:
    def test_torn_publish_adopt_gc_epoch(self):
        from repro.serve.shm import (ShmPublisher, ShmReplica,
                                     WriterDeadError, _Segment, _untrack)
        prefix = f"tfault{os.getpid()}"
        # boot-time GC: a leaked (untracked) orphan data segment from a
        # kill-9'd writer is reclaimed, and republishing its version
        # number does not collide
        orphan = _Segment(name=f"{prefix}.v7", create=True, size=4096)
        _untrack(orphan._name)
        orphan.close()
        pub = ShmPublisher(prefix)
        try:
            assert pub.reclaimed >= 1
            pub.publish(1, 1, {"a": np.arange(6.)})
            rep = ShmReplica(prefix, connect_timeout=10,
                             seqlock_spin_s=0.15)
            held = rep.current()
            assert (held.epoch, held.version) == (1, 1)

            # child adopts the prefix and dies mid-seqlock-swing
            child = f"""
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.serve.faults import FaultPlan
from repro.serve.shm import ShmPublisher
plan = FaultPlan.build(FaultPlan.torn_publish(0, at_version=2))
p = ShmPublisher({prefix!r},
                 fault=plan.for_component("writer", 0))
p.publish(2, 9, {{"a": np.arange(8.)}})
raise SystemExit("unreachable")
"""
            proc = subprocess.run([sys.executable, "-c", child],
                                  env=_env(), capture_output=True,
                                  text=True, timeout=300)
            assert proc.returncode == KILL_EXIT_CODE, proc.stderr

            # stuck-odd protocol: bounded spin → re-attach → declared
            # dead with a pid liveness probe; the held snapshot stays
            # bit-identical all along
            with pytest.raises(WriterDeadError) as ei:
                rep.read_control()
            assert not ei.value.alive
            assert np.array_equal(held.arrays["a"], np.arange(6.))

            # restart: adopt (epoch chain continues through the dead
            # child's own adoption), republish the same version number
            pub2 = ShmPublisher(prefix)
            assert pub2.epoch >= 3           # 1 → child 2 → us 3
            assert pub2.resumed_version == 2
            pub2.publish(2, 9, {"a": np.full(8, 5.0)})
            got = rep.current()
            assert (got.epoch, got.version) == (pub2.epoch, 2)
            assert np.array_equal(got.arrays["a"], np.full(8, 5.0))
            rep.close()
            pub2.close()
        finally:
            try:
                pub.close(unlink=False)
            except Exception:
                pass

    def test_replica_service_signals_writer_dead(self):
        from repro.serve.shm import ReplicaService, ShmPublisher
        prefix = f"tdead{os.getpid()}"
        pub = ShmPublisher(prefix)
        rng = np.random.default_rng(1)
        m = StreamingMiner(SIZES, seed=1)
        m.upsert(rng.integers(0, SIZES, size=(80, 3)).astype(np.int64))
        idx = ClusterIndex.from_result(m.snapshot())
        arrays = {"packed_sigs": idx.packed_sigs,
                  "any_pairs": idx.any_pairs,
                  "scores": np.zeros(len(idx)),
                  "ages": np.zeros(len(idx)),
                  "density": np.asarray(idx.density, np.float64),
                  "gen_count": np.asarray(idx.gen_count, np.int64),
                  "volume": np.asarray(idx.volume, np.float64)}
        for k in range(idx.arity):
            arrays[f"mode_pairs_{k}"] = idx.mode_pairs[k]
            arrays[f"comp_ents_{k}"] = idx.comp_ents[k]
            arrays[f"comp_bounds_{k}"] = idx.comp_bounds[k]
        pub.publish(1, 1, arrays, meta={"n_modes": idx.arity})
        deaths = []
        svc = ReplicaService(prefix, poll_interval=0.01,
                             connect_timeout=10, seqlock_spin_s=0.1,
                             on_writer_dead=deaths.append,
                             dead_signal_cooldown=0.0)
        svc.start(first_snapshot_timeout=30)
        try:
            v = svc.version
            # wedge the seqlock odd by hand — a writer dead mid-swing
            import struct
            pub._seq += 1
            struct.pack_into("<Q", pub._ctl.buf, 0, pub._seq)
            _wait_for(lambda: len(deaths) >= 1, timeout=30,
                      what="writer-dead signal")
            # the replica keeps serving its held snapshot and its
            # /health stays alive (thread_alive True — the attach loop
            # survived the WriterDeadError)
            assert svc.version == v and svc.thread_alive
            assert svc.stats()["writer_dead_signals"] >= 1
            # writer finishes the swing: the replica recovers silently
            pub._seq += 1
            struct.pack_into("<Q", pub._ctl.buf, 0, pub._seq)
            assert svc.query(entity=0, k=2).version == v
        finally:
            svc.stop()
            pub.close()


# ---------------------------------------------------------------------------
# Integrity plane (ISSUE 8): CRC-framed WAL/checkpoint, quarantine +
# generation fallback, torn-tail truncation
# ---------------------------------------------------------------------------

def _chunks(seed, n_chunks=5, rows=4):
    rng = np.random.default_rng(seed)
    return rng.integers(0, SIZES,
                        size=(n_chunks, rows, 3)).astype(np.int64)


class TestCorruptionRecovery:
    def test_interior_wal_flip_quarantines_and_replays_prefix(
            self, tmp_path):
        """A verified record *after* a corrupt one makes the WAL
        poisoned: quarantine the file, replay only the verified prefix,
        and cut a fresh checkpoint so the prefix stays durable."""
        rec = str(tmp_path / "rec")
        chunks = _chunks(31)
        vic = TriclusterService(SIZES, seed=31, recover_dir=rec,
                                checkpoint_every=10**6)
        for c in chunks:
            vic.add(c)
        del vic                              # crash, WAL holds 5 records

        wal = os.path.join(rec, "wal.jsonl")
        with open(wal, "rb") as f:
            lines = f.read().split(b"\n")
        ln = lines[1]                        # rot record 2 of 5
        pos = len(ln) - 3                    # inside the json payload
        lines[1] = ln[:pos] + bytes([ln[pos] ^ 0x01]) + ln[pos + 1:]
        with open(wal, "wb") as f:
            f.write(b"\n".join(lines))

        successor = TriclusterService(SIZES, seed=31, recover_dir=rec,
                                      checkpoint_every=10**6)
        r = successor.recovered
        assert r["wal_crc_errors"] == 1
        assert r["wal_quarantined"].startswith("wal.jsonl.quarantine.")
        assert r["replayed_ops"] == 1        # the verified prefix only
        assert successor.stream_version == 1
        assert glob.glob(os.path.join(rec, "wal.jsonl.quarantine.*"))
        # the replayed prefix was made durable immediately
        assert successor.stats()["checkpoints"] >= 1
        assert os.path.exists(os.path.join(rec, "ckpt.npz"))

        ctl = TriclusterService(SIZES, seed=31)
        ctl.add(chunks[0])
        ctl.refresh()
        successor.refresh()
        assert _top_sigs(successor) == _top_sigs(ctl)
        ctl.stop()
        successor.stop()

    def test_torn_tail_truncates_and_resumes_in_place(self, tmp_path):
        """A corrupt *last* record is a torn append: drop it, truncate
        the file, and keep appending — no quarantine, no data loss
        beyond the half-written op that never acked."""
        rec = str(tmp_path / "rec")
        chunks = _chunks(37)
        vic = TriclusterService(SIZES, seed=37, recover_dir=rec,
                                checkpoint_every=10**6)
        for c in chunks[:3]:
            vic.add(c)
        del vic
        wal = os.path.join(rec, "wal.jsonl")
        good = os.path.getsize(wal)
        with open(wal, "ab") as f:           # the torn half-record
            f.write(b'00000000 {"op": "add", "rows"')

        successor = TriclusterService(SIZES, seed=37, recover_dir=rec,
                                      checkpoint_every=10**6)
        r = successor.recovered
        assert r["wal_torn_tail"] == 1 and r["wal_quarantined"] == ""
        assert r["replayed_ops"] == 3
        assert successor.stream_version == 3
        assert os.path.getsize(wal) == good  # truncated to the prefix
        assert not glob.glob(wal + ".quarantine.*")
        successor.add(chunks[3])             # resume appending in place
        del successor

        final = TriclusterService(SIZES, seed=37, recover_dir=rec,
                                  checkpoint_every=10**6)
        assert final.recovered["replayed_ops"] == 4
        assert final.stream_version == 4
        final.stop()

    def test_truncated_checkpoint_falls_back_a_generation(
            self, tmp_path):
        """The injected checkpoint truncation: the framed header
        promises more bytes than the file holds, load refuses, and
        recovery restores the rotated previous generation + the WAL
        tail — data loss bounded to the ops between the generations."""
        rec = str(tmp_path / "rec")
        chunks = _chunks(41)
        plan = FaultPlan.build(
            FaultPlan.truncate_checkpoint(0, at_version=2))
        vic = TriclusterService(SIZES, seed=41, recover_dir=rec,
                                checkpoint_every=2,
                                fault=plan.for_component("writer", 0))
        vic.add(chunks[0])
        vic.add(chunks[1])
        vic.refresh()                        # gen 1 (sv=2), version 1
        vic.add(chunks[2])
        vic.add(chunks[3])
        vic.refresh()                        # gen 2 (sv=4) — truncated
        vic.add(chunks[4])                   # WAL: sv=5
        assert vic.stats()["checkpoints"] == 2
        del vic

        successor = TriclusterService(SIZES, seed=41, recover_dir=rec,
                                      checkpoint_every=10**6)
        r = successor.recovered
        assert r["checkpoint_generation"] == "previous"
        assert r["checkpoint_quarantined"] == 1
        assert r["checkpoint_stream_version"] == 2
        assert r["replayed_ops"] == 1        # sv=5 from the WAL
        assert successor.stream_version == 5
        assert glob.glob(os.path.join(rec, "ckpt.npz.quarantine.*"))
        rs = successor.resilience_stats()
        assert rs["checkpoint_generation_fallbacks"] == 1

        # bit-identical to a control over the surviving ops (chunks
        # 2/3 — the window between the generations — are the loss)
        ctl = TriclusterService(SIZES, seed=41)
        ctl.add(chunks[0])
        ctl.add(chunks[1])
        ctl.add(chunks[4])
        ctl.refresh()
        successor.refresh()
        assert _top_sigs(successor) == _top_sigs(ctl)
        ctl.stop()
        successor.stop()

    def test_injected_wal_flip_end_to_end(self, tmp_path):
        """``flip_wal_byte`` at sv=3 of 5: the victim's in-memory state
        is untouched (the lie is only on disk), the successor detects
        it at replay, quarantines, and keeps the verified prefix."""
        rec = str(tmp_path / "rec")
        chunks = _chunks(43)
        plan = FaultPlan.build(
            FaultPlan.flip_wal_byte(0, at_stream_version=3))
        vic = TriclusterService(SIZES, seed=43, recover_dir=rec,
                                checkpoint_every=10**6,
                                fault=plan.for_component("writer", 0))
        for c in chunks:
            vic.add(c)
        assert vic.stream_version == 5       # victim never noticed
        del vic

        successor = TriclusterService(SIZES, seed=43, recover_dir=rec)
        r = successor.recovered
        assert r["wal_crc_errors"] == 1 and r["wal_quarantined"]
        assert r["replayed_ops"] == 2 and successor.stream_version == 2
        assert successor.resilience_stats()["wal_quarantined"] == 1
        successor.stop()

    def test_checkpoint_frame_rejects_bit_rot_and_truncation(
            self, tmp_path):
        from repro.core import runs as RS
        rec = str(tmp_path)
        svc = TriclusterService(SIZES, seed=47, recover_dir=rec)
        svc.add(_chunks(47)[0])
        assert svc.final_checkpoint()
        svc.stop()
        path = os.path.join(rec, "ckpt.npz")
        RS.load_checkpoint(path)             # clean frame verifies
        with open(path, "rb") as f:
            data = f.read()
        i = len(data) // 2
        with open(path, "wb") as f:
            f.write(data[:i] + bytes([data[i] ^ 0xFF]) + data[i + 1:])
        with pytest.raises(RS.CheckpointCorruptError):
            RS.load_checkpoint(path)
        with open(path, "wb") as f:          # torn write: short payload
            f.write(data[:len(data) // 2])
        with pytest.raises(RS.CheckpointCorruptError):
            RS.load_checkpoint(path)
        with open(path, "wb") as f:          # trailing garbage
            f.write(data + b"x")
        with pytest.raises(RS.CheckpointCorruptError):
            RS.load_checkpoint(path)


# ---------------------------------------------------------------------------
# Background scrubber: cross-structure invariants → /health 503
# ---------------------------------------------------------------------------

class TestScrubber:
    def test_scrub_clean_then_violation_flips_health(self, tmp_path):
        svc = _service(seed=9, scrub_interval=0.02,
                       event_dir=str(tmp_path), event_name="w0")
        svc.refresh()
        svc.start()
        try:
            _wait_for(lambda: svc.resilience_stats()["scrubs"] >= 1,
                      what="first background scrub")
            rep = svc.scrub()
            assert rep["violations"] == [] and svc.scrub_clean
            rs = svc.resilience_stats()
            assert rs["last_scrub_version"] == svc.version
            h = health_doc(svc)
            assert h["healthy"] and h["scrub_clean"]

            # a snapshot whose ranking scores went non-finite: the
            # scrubber must flag it and /health must eject the backend
            snap = svc._snap
            poisoned = types.SimpleNamespace(
                version=snap.version + 1, index=snap.index,
                result=snap.result,
                querier=types.SimpleNamespace(
                    scores=np.array([1.0, np.nan])),
                ages=snap.ages)
            rep = svc.scrub(poisoned)
            assert "non-finite ranking scores" in rep["violations"]
            assert not svc.scrub_clean
            h = health_doc(svc)
            assert not h["healthy"] and not h["scrub_clean"]
            assert "scrub" in h["error"]
            assert any(e[0] == "scrub_violation"
                       for e in svc._stats["integrity_events"])
            # the violation was mirrored to the supervisor event file
            assert os.path.exists(str(tmp_path / "w0.events"))
        finally:
            svc.stop()

    def test_scrub_catches_index_result_divergence(self):
        svc = _service(seed=10)
        svc.refresh()
        snap = svc._snap
        assert len(snap.index) > 1
        # an index that silently lost a cluster row relative to
        # result.keep — exactly the divergence delta maintenance bugs
        # (or rotted inputs) would produce
        smaller = types.SimpleNamespace(
            packed_sigs=snap.index.packed_sigs[:-1])
        poisoned = types.SimpleNamespace(
            version=snap.version + 1, index=smaller, result=snap.result,
            querier=snap.querier, ages=snap.ages)
        rep = svc.scrub(poisoned)
        assert any("divergence" in v for v in rep["violations"])
        svc.stop()


# ---------------------------------------------------------------------------
# Shm integrity: manifest CRCs refuse a rotted segment; the replica
# holds its snapshot, escalates, and recovers on the next clean publish
# ---------------------------------------------------------------------------

def _index_arrays(seed=1, n=80):
    rng = np.random.default_rng(seed)
    m = StreamingMiner(SIZES, seed=seed)
    m.upsert(rng.integers(0, SIZES, size=(n, 3)).astype(np.int64))
    idx = ClusterIndex.from_result(m.snapshot())
    arrays = {"packed_sigs": idx.packed_sigs,
              "any_pairs": idx.any_pairs,
              "scores": np.zeros(len(idx)),
              "ages": np.zeros(len(idx)),
              "density": np.asarray(idx.density, np.float64),
              "gen_count": np.asarray(idx.gen_count, np.int64),
              "volume": np.asarray(idx.volume, np.float64)}
    for k in range(idx.arity):
        arrays[f"mode_pairs_{k}"] = idx.mode_pairs[k]
        arrays[f"comp_ents_{k}"] = idx.comp_ents[k]
        arrays[f"comp_bounds_{k}"] = idx.comp_bounds[k]
    return arrays, idx.arity


def _hit_sigs(out):
    return [(int(v.signature[0]), int(v.signature[1]))
            for v, _ in out.hits]


@pytest.mark.skipif(not os.path.isdir("/dev/shm"),
                    reason="POSIX shm namespace required")
class TestShmIntegrity:
    def test_flip_fault_refused_at_attach(self):
        from repro.serve.shm import (ShmCorruptionError, ShmPublisher,
                                     ShmReplica)
        prefix = f"tcor{os.getpid()}"
        plan = FaultPlan.build(FaultPlan.flip_shm_word(0, at_version=2))
        pub = ShmPublisher(prefix, fault=plan.for_component("writer", 0))
        try:
            pub.publish(1, 1, {"a": np.arange(64.)})
            rep = ShmReplica(prefix, connect_timeout=10,
                             seqlock_spin_s=0.2)
            held = rep.current()
            assert held.version == 1 and held.verify() == []
            pub.publish(2, 2, {"a": np.arange(64.) * 2})
            with pytest.raises(ShmCorruptionError, match="checksum"):
                rep.current()
            # the held bundle still serves the verified bytes
            assert np.array_equal(held.arrays["a"], np.arange(64.))
            rep.close()
        finally:
            pub.close()

    def test_replica_holds_snapshot_escalates_and_recovers(self):
        from repro.serve.shm import (ReplicaService, ShmCorruptionError,
                                     ShmPublisher)
        prefix = f"trsc{os.getpid()}"
        plan = FaultPlan.build(FaultPlan.flip_shm_word(0, at_version=2))
        pub = ShmPublisher(prefix, fault=plan.for_component("writer", 0))
        arrays, n_modes = _index_arrays(seed=1)
        pub.publish(1, 1, arrays, meta={"n_modes": n_modes})
        deaths = []
        svc = ReplicaService(prefix, poll_interval=0.01,
                             connect_timeout=10, seqlock_spin_s=0.2,
                             on_writer_dead=deaths.append,
                             dead_signal_cooldown=0.0,
                             scrub_interval=0.02)
        svc.start(first_snapshot_timeout=30)
        try:
            assert svc.version == 1
            base = _hit_sigs(svc.query(k=3))
            pub.publish(2, 2, arrays, meta={"n_modes": n_modes})
            _wait_for(lambda: (svc.resilience_stats()
                               ["shm_corruptions"]) >= 1,
                      what="corrupt segment refused")
            # zero wrong answers: the rotted v2 never serves — the held
            # v1 snapshot answers, bit-identical to before the rot
            assert svc.version == 1
            assert _hit_sigs(svc.query(k=3)) == base
            assert deaths and isinstance(deaths[0], ShmCorruptionError)
            # next clean publish recovers (the flip fault is spent)
            pub.publish(3, 3, arrays, meta={"n_modes": n_modes})
            _wait_for(lambda: svc.version == 3,
                      what="clean republish attached")
            assert svc.scrub_clean and health_doc(svc)["healthy"]
            assert _hit_sigs(svc.query(k=3)) == base
        finally:
            svc.stop()
            pub.close()

    def test_opportunistic_scrub_catches_post_attach_rot(self):
        from repro.serve.shm import ReplicaService, ShmPublisher
        prefix = f"tsrb{os.getpid()}"
        pub = ShmPublisher(prefix)
        arrays, n_modes = _index_arrays(seed=2)
        pub.publish(1, 1, arrays, meta={"n_modes": n_modes})
        deaths = []
        svc = ReplicaService(prefix, poll_interval=0.01,
                             connect_timeout=10, seqlock_spin_s=0.2,
                             on_writer_dead=deaths.append,
                             dead_signal_cooldown=0.0,
                             scrub_interval=0.01)
        svc.start(first_snapshot_timeout=30)
        try:
            assert svc.version == 1 and svc.scrub_clean
            # rot one byte of the held segment *after* the verified
            # attach, through the writer's live mapping — only the
            # rotating background re-verify can see this
            spec = svc.replica._bundle.manifest[0]
            o = int(spec["offset"])
            pub._data.buf[o] = pub._data.buf[o] ^ 0xFF
            _wait_for(lambda: not svc.scrub_clean,
                      what="scrub caught held-bundle rot")
            assert svc.resilience_stats()["scrub_violations"]
            assert not health_doc(svc)["healthy"]
            assert deaths                       # supervisor escalation
            # a clean republish supersedes the corrupt bundle
            pub.publish(2, 2, arrays, meta={"n_modes": n_modes})
            _wait_for(lambda: svc.version == 2,
                      what="clean republish attached")
            assert svc.scrub_clean and health_doc(svc)["healthy"]
        finally:
            svc.stop()
            pub.close()


# ---------------------------------------------------------------------------
# Write backpressure: 429 + Retry-After past --max-write-backlog
# ---------------------------------------------------------------------------

class TestBackpressure:
    def test_429_retry_after_and_drain(self):
        rng = np.random.default_rng(21)
        svc = TriclusterService(SIZES, refresh_interval=0.05, seed=21)
        svc.add(rng.integers(0, SIZES, size=(40, 3)).astype(np.int64))
        svc.refresh()                        # warm the miner, dirty=0
        server = make_server(svc, port=0, max_write_backlog=2)
        threading.Thread(target=server.serve_forever,
                         daemon=True).start()
        try:
            cl = ClusterClient(f"http://127.0.0.1:{server.port}",
                               timeout=30)
            assert cl.upsert([[0, 0, 0]])["stream_version"] == 2
            assert cl.upsert([[1, 1, 1]])["stream_version"] == 3
            # backlog at the limit: 429; the client honours Retry-After
            # exactly once, the backlog is still there, error surfaces
            t0 = time.monotonic()
            with pytest.raises(RuntimeError, match="backlog"):
                cl.upsert([[2, 2, 2]])
            assert time.monotonic() - t0 >= 0.1      # 2x refresh_interval
            assert server.throttled_writes == 2
            assert svc.stream_version == 3           # write rejected
            # direct drain: the very next write is admitted
            svc.refresh()
            assert cl.upsert([[2, 2, 2]])["stream_version"] == 4
            assert cl.upsert([[3, 3, 3]])["stream_version"] == 5
            # retry-once path that *succeeds*: a drain lands while the
            # client sleeps its Retry-After
            def _drain():
                _wait_for(lambda: server.throttled_writes >= 3,
                          what="third throttle")
                svc.refresh()
            t = threading.Thread(target=_drain, daemon=True)
            t.start()
            assert cl.upsert([[4, 4, 4]])["stream_version"] == 6
            t.join(timeout=30)
            assert server.throttled_writes == 3
        finally:
            server.shutdown()
            server.server_close()
            svc.stop()


# ---------------------------------------------------------------------------
# Supervisor event log: bounded rotation + child event ingestion
# ---------------------------------------------------------------------------

class TestSupervisorEvents:
    def test_event_log_rotates_bounded(self):
        sup = Supervisor(max_events=8)
        for i in range(30):
            sup._event("x", "e", str(i))
        assert len(sup.events) <= 8
        assert sup.events[0][0] == "<supervisor>"
        assert sup.events[0][1] == "rotated"
        assert sup.events_dropped >= 20
        assert sup.events[-1] == ("x", "e", "29")    # newest survive

    def test_child_events_ingested_from_flag_dir(self, tmp_path):
        from repro.serve.supervise import write_event
        flag_dir = str(tmp_path)
        write_event(flag_dir, "shard-0", "wal_quarantined",
                    "interior record corrupt at line 3")
        sup = Supervisor(poll_interval=0.02, flag_dir=flag_dir)
        sup.add("shard-0", _sleeper)
        with sup:
            _wait_for(lambda: any(e[1] == "wal_quarantined"
                                  for e in sup.events),
                      what="child event ingested")
        name, event, detail = [e for e in sup.events
                               if e[1] == "wal_quarantined"][0]
        assert name == "shard-0" and "line 3" in detail
        assert not os.path.exists(
            os.path.join(flag_dir, "shard-0.events"))
        assert not os.path.exists(
            os.path.join(flag_dir, "shard-0.events.ingest"))
