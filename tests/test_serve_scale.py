"""Sharded serving plane (ISSUE 6): delta-maintained ClusterIndex
property tests, zero-copy shared-memory replica fidelity, and the
router's ranked-hit merge.

The delta invariant under test is the serving plane's backbone: for ANY
interleaving of upserts and deletes, ``ClusterIndex.delta_from_result``
(the O(changed) overlay build) must be *bit-identical* — stacked
membership words, bounds, stats and per-view components — to a fresh
``from_result`` rebuild of the same snapshot, including when deltas are
chained snapshot-over-snapshot and when the self-compaction heuristic
falls back to a full build mid-sequence.
"""
import hashlib
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import StreamingMiner
from repro.data import synthetic
from repro.serve.clusters import ClusterIndex


def _assert_identical(full: ClusterIndex, delta: ClusterIndex) -> None:
    """Bit-identity of the two builds: every stacked array, every stat,
    and (sampled) every per-view component set."""
    assert np.array_equal(full.packed_sigs, delta.packed_sigs)
    assert np.array_equal(full.any_pairs, delta.any_pairs)
    for k in range(full.arity):
        assert np.array_equal(full.mode_pairs[k], delta.mode_pairs[k])
        assert np.array_equal(full.comp_ents[k], delta.comp_ents[k])
        assert np.array_equal(full.comp_bounds[k], delta.comp_bounds[k])
    for name in ("sig_lo", "sig_hi", "density", "gen_count", "volume"):
        assert np.array_equal(getattr(full, name), getattr(delta, name)), name
    step = max(1, len(full) // 17)
    for row in range(0, len(full), step):
        va, vb = full.view_at(row), delta.view_at(row)
        assert va.signature == vb.signature
        assert tuple(va.components) == tuple(vb.components)


@pytest.mark.parametrize("seed", [11, 29])
def test_delta_bit_identical_random_interleavings(seed):
    sizes = (60, 40, 20)
    rng = np.random.default_rng(seed)
    m = StreamingMiner(sizes, seed=seed)
    inserted = rng.integers(0, sizes, size=(400, 3)).astype(np.int64)
    m.upsert(inserted)
    prev_res = m.snapshot()
    prev_idx = ClusterIndex.from_result(prev_res)
    for step in range(6):
        op = rng.integers(0, 3)
        if op == 0:        # small novel upsert → few dirty clusters
            rows = rng.integers(0, sizes, size=(3, 3)).astype(np.int64)
            m.upsert(rows)
            inserted = np.concatenate((inserted, rows))
        elif op == 1:      # delete a few live tuples → tombstones
            take = rng.integers(0, len(inserted), 4)
            m.delete(inserted[take])
        else:              # bulk churn → the compaction fallback path
            rows = rng.integers(0, sizes, size=(120, 3)).astype(np.int64)
            m.upsert(rows)
            inserted = np.concatenate((inserted, rows))
        res = m.snapshot()
        full = ClusterIndex.from_result(res)
        delta = ClusterIndex.delta_from_result(prev_idx, res)
        # query parity BEFORE any flat-array materialisation: the
        # overlay answers probes without touching the O(M) arrays
        for e in (0, 1, int(rng.integers(0, sizes[0]))):
            for mode in (None, 0, 1, 2):
                assert np.array_equal(full.entity_rows(e, mode),
                                      delta.entity_rows(e, mode)), \
                    (step, e, mode)
        _assert_identical(full, delta)
        # chain: the (now materialised) delta is the next base
        prev_idx = delta


def test_delta_chains_without_materialising():
    """Deltas chained over an *un-materialised* overlay index stay
    bit-identical — the swap path never needs the flat arrays."""
    sizes = (50, 30, 15)
    rng = np.random.default_rng(5)
    m = StreamingMiner(sizes, seed=5)
    m.upsert(rng.integers(0, sizes, size=(300, 3)).astype(np.int64))
    prev = ClusterIndex.from_result(m.snapshot())
    for _ in range(3):
        m.upsert(rng.integers(0, sizes, size=(2, 3)).astype(np.int64))
        res = m.snapshot()
        prev = ClusterIndex.delta_from_result(prev, res)
        assert prev.supports_delta
    full = ClusterIndex.from_result(res)
    _assert_identical(full, prev)


_CHILD = r"""
import hashlib, json, sys
from repro.serve.shm import ShmReplica

prefix = sys.argv[1]
rep = ShmReplica(prefix, connect_timeout=30.0)
bundle = rep.current()
out = {"version": bundle.version,
       "stream_version": bundle.stream_version,
       "hashes": {k: hashlib.sha256(v.tobytes()).hexdigest()
                  for k, v in sorted(bundle.arrays.items())}}
print(json.dumps(out))
rep.close()
"""


def test_replica_process_observes_exact_writer_arrays(tmp_path):
    """A separate reader process attaches the published segment and
    must see byte-for-byte the arrays the writer laid out."""
    shm = pytest.importorskip("repro.serve.shm")
    ctx = synthetic.random_context((8, 7, 6), 96, seed=7)
    m = StreamingMiner(ctx.sizes, seed=7)
    m.upsert(ctx.tuples)
    idx = ClusterIndex.from_result(m.snapshot())
    arrays = {"packed_sigs": idx.packed_sigs, "any_pairs": idx.any_pairs,
              "density": idx.density}
    for k in range(idx.arity):
        arrays[f"mode_pairs_{k}"] = idx.mode_pairs[k]
        arrays[f"comp_ents_{k}"] = idx.comp_ents[k]
        arrays[f"comp_bounds_{k}"] = idx.comp_bounds[k]
    prefix = f"trs-test-{os.getpid()}"
    pub = shm.ShmPublisher(prefix)
    try:
        pub.publish(3, 17, arrays, meta={"n_modes": idx.arity})
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ("src", env.get("PYTHONPATH", "")) if p)
        proc = subprocess.run([sys.executable, "-c", _CHILD, prefix],
                              capture_output=True, text=True, timeout=120,
                              env=env)
        assert proc.returncode == 0, proc.stderr
        got = json.loads(proc.stdout)
    finally:
        pub.close()
    assert got["version"] == 3 and got["stream_version"] == 17
    want = {k: hashlib.sha256(np.ascontiguousarray(v).tobytes()).hexdigest()
            for k, v in arrays.items()}
    assert got["hashes"] == want


def test_reader_waits_out_in_progress_publish(tmp_path):
    """A reader that catches the seqlock odd (writer mid-swing) spins
    — bounded — and completes with a consistent control read the moment
    the writer lands the even sequence; it must never return a torn
    (odd-observed) control block."""
    import struct
    import threading

    shm = pytest.importorskip("repro.serve.shm")
    prefix = f"trs-odd-{os.getpid()}"
    pub = shm.ShmPublisher(prefix)
    try:
        pub.publish(1, 5, {"a": np.arange(4.0)})
        rep = shm.ShmReplica(prefix, connect_timeout=10.0,
                             seqlock_spin_s=30.0)
        # wedge the seqlock odd by hand: a publish in progress
        pub._seq += 1
        struct.pack_into("<Q", pub._ctl.buf, 0, pub._seq)
        out = {}
        started = threading.Event()

        def read():
            started.set()
            out["ctl"] = rep.read_control()

        t = threading.Thread(target=read, daemon=True)
        t.start()
        started.wait(10)
        assert t.is_alive()                  # spinning on the odd seq
        # writer completes the swing: new payload, then even sequence
        struct.pack_into(shm._CTL_FMT, pub._ctl.buf, 0, pub._seq,
                         pub.epoch, 2, 9, 0.0, 0, 0)
        pub._seq += 1
        struct.pack_into("<Q", pub._ctl.buf, 0, pub._seq)
        t.join(timeout=10)
        assert not t.is_alive()
        # the reader saw the *completed* publish, never the torn state
        assert out["ctl"]["version"] == 2
        assert out["ctl"]["stream_version"] == 9
        rep.close()
    finally:
        pub.close()


def test_held_bundle_survives_unlink_bit_identical(tmp_path):
    """The single-reference swap contract: after the writer publishes
    v2 and unlinks v1, a reader still holding v1's bundle answers
    bit-identically from the (name-unlinked, memory-held) segment."""
    shm = pytest.importorskip("repro.serve.shm")
    prefix = f"trs-unlink-{os.getpid()}"
    a1 = {"a": np.arange(32, dtype=np.float64),
          "b": np.arange(8, dtype=np.int64) * 3}
    want = {k: hashlib.sha256(v.tobytes()).hexdigest()
            for k, v in a1.items()}
    pub = shm.ShmPublisher(prefix)
    try:
        pub.publish(1, 1, a1)
        rep = shm.ShmReplica(prefix, connect_timeout=10.0)
        held = rep.current()
        assert held.version == 1
        pub.publish(2, 2, {"a": np.zeros(32), "b": np.zeros(8, np.int64)})
        # v1's name is gone from the namespace...
        assert not os.path.exists(f"/dev/shm/{prefix}.v1") \
            or not os.path.isdir("/dev/shm")
        # ...but the held mapping still reads back byte-for-byte
        got = {k: hashlib.sha256(v.tobytes()).hexdigest()
               for k, v in held.arrays.items()}
        assert got == want
        # and the replica's next attach follows the swap to v2
        cur = rep.current()
        assert cur.version == 2 and float(cur.arrays["a"].sum()) == 0.0
        rep.close()
    finally:
        pub.close()


def test_router_merge_ranks_dedups_truncates():
    from repro.serve.router import _merge_hits

    def hit(sig, score):
        return {"signature": list(sig), "score": score}

    a = [hit((1, 0), 0.9), hit((2, 0), 0.5), hit((3, 0), 0.1)]
    b = [hit((4, 0), 0.7), hit((1, 0), 0.9), hit((5, 0), 0.3)]
    merged = _merge_hits([a, b], k=4)
    assert [tuple(h["signature"]) for h in merged] \
        == [(1, 0), (4, 0), (2, 0), (5, 0)]          # global best-first,
    # the duplicate signature (1,0) kept once (best/first occurrence),
    # truncated to k
    scores = [h["score"] for h in merged]
    assert scores == sorted(scores, reverse=True)
    assert _merge_hits([[], []], k=3) == []
