"""Checkpointing: atomic roundtrip, corruption detection, async save, GC,
and elastic re-shard across device counts (subprocess)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoints import CheckpointManager

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 6)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)},
            "e": [jnp.ones((2, 2)), jnp.zeros((3,))]}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(7, t, metadata={"note": "x"})
    step, out = mgr.restore(template=t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.metadata() == {"note": "x"}


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), block=False)
    mgr.wait()
    assert mgr.steps() == [3, 4]
    step, out = mgr.restore(template=_tree())
    assert step == 4


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    path = mgr._path(1)
    victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, victim))
    np.save(os.path.join(path, victim), arr + 1)
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(template=_tree())


def test_partial_save_never_commits(tmp_path):
    """A crash mid-save (simulated: stray .tmp dir) must be invisible."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    os.makedirs(mgr._path(2, tmp=True))      # simulated dead tmp
    assert mgr.latest_step() == 1
    mgr.save(2, _tree(2))                    # overwrites the stray tmp
    assert mgr.latest_step() == 2


_ELASTIC = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n)d"
import sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoints import CheckpointManager

from repro.launch.mesh import make_mesh
mesh = make_mesh((%(n)d,), ("data",))
sh = NamedSharding(mesh, P("data"))
mgr = CheckpointManager(sys.argv[1])
tmpl = {"w": jnp.zeros((16, 4))}
if sys.argv[2] == "save":
    w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(16, 4), sh)
    mgr.save(3, {"w": w})
else:
    step, out = mgr.restore(template=tmpl, shardings={"w": sh})
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(out["w"]),
        np.arange(64, dtype=np.float32).reshape(16, 4))
    assert len(out["w"].sharding.device_set) == %(n)d
print("OK")
'''


def test_elastic_reshard(tmp_path):
    """Save on an 8-device mesh, restore on 4 — elastic re-scale."""
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    for n, mode in ((8, "save"), (4, "load")):
        proc = subprocess.run(
            [sys.executable, "-c", _ELASTIC % {"n": n},
             str(tmp_path), mode],
            capture_output=True, text=True, env=env, timeout=300)
        assert "OK" in proc.stdout, proc.stderr[-1500:]
