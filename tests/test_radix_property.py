"""Properties of the radix sort backend (``core.radix``):

* the radix permutation is bit-identical to a stable ``lax.sort`` with
  an iota payload — including payload order among duplicate keys — for
  1- and 2-word keys, pruned pass plans, both device formulations
  (composite-word and Pallas histogram/rank kernels), and the host LSD
  argsort the streaming engine's chunk runs use,
* radix-backed mining equals the lax-backed *and* lexsort pipelines
  leaf-for-leaf (every ``PipelineResult`` field, permutations
  included), prime and NOAC, and the >64-bit lexsort fallback engages
  transparently,
* pass schedules prune to the plan's live bits (a 22-bit key never
  pays 64 bits of passes),
* the cardinality-pruned (rank-coded) value lane packs host≡device,
  orders exactly like the 32-bit float lane, and leaves every mining
  leaf bit-identical — δ-window queries included.

The seeded tests below always run; the hypothesis classes widen the
search in CI (the container has no hypothesis — same pattern as
``tests/test_keys_property.py``).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BatchMiner, NOACMiner
from repro.core import keys as K
from repro.core import radix as RX


def _ref_sort(words, nw):
    t = words[0].shape[0]
    return jax.lax.sort(tuple(words) + (jnp.arange(t, dtype=jnp.int32),),
                        num_keys=nw, is_stable=True)


def _random_words(rng, t, live_bits, dup_frac=0.3):
    """Random packed key words with a controlled duplicate fraction
    (duplicates are what distinguishes a stable sort from any sort)."""
    n_distinct = max(1, int(t * (1.0 - dup_frac)))
    pool = rng.integers(0, 1 << min(live_bits, 63), n_distinct,
                        dtype=np.uint64)
    keys = pool[rng.integers(0, n_distinct, t)]
    if live_bits > 32:
        return (jnp.asarray((keys >> np.uint64(32)).astype(np.uint32)),
                jnp.asarray(keys.astype(np.uint32)))
    return (jnp.asarray(keys.astype(np.uint32)),)


@pytest.mark.parametrize("t", [1, 3, 257, 2000])
@pytest.mark.parametrize("live_bits", [1, 7, 15, 22, 28, 32, 33, 47, 60, 64])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_radix_perm_matches_stable_lax_sort(t, live_bits, use_pallas):
    if use_pallas and t > 300:
        pytest.skip("interpret-mode kernels are slow at size")
    rng = np.random.default_rng(t * 131 + live_bits)
    words = _random_words(rng, t, live_bits)
    ref = _ref_sort(words, len(words))
    perm = RX.radix_sort_perm(words, live_bits, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(perm), np.asarray(ref[-1]))
    s_words, (pay,) = K.sort_with_payload(
        words, (jnp.arange(t, dtype=jnp.int32),), backend="radix",
        live_bits=live_bits, use_pallas=use_pallas)
    for got, want in zip(s_words + (pay,), ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pass_schedule_prunes_to_live_bits():
    # 22 live bits at T<=32k: 17-bit digits -> 2 passes, never 64 bits' worth
    plan = RX.plan_radix(22, 30_000)
    assert plan.passes == 2
    assert sum(plan.widths) == 22
    assert plan.pos_bits == 15
    # the 8-bit histogram formulation of the issue's example: 3 passes
    assert RX.plan_radix(22, 30_000, digit_bits=8).passes == 3
    # degenerate and full-width cases
    assert RX.plan_radix(1, 4).passes == 1
    assert RX.plan_radix(64, 120_000).passes == 5   # 15-bit digits
    with pytest.raises(ValueError):
        RX.plan_radix(22, 30_000, digit_bits=32)


def test_resolve_sort_backend():
    assert RX.resolve_sort_backend(None, None, True) == "radix"
    assert RX.resolve_sort_backend("auto", True, True) == "radix"
    assert RX.resolve_sort_backend("lax", None, True) == "lax"
    assert RX.resolve_sort_backend(None, False, True) == "lexsort"
    assert RX.resolve_sort_backend("lexsort", True, True) == "lexsort"
    assert RX.resolve_sort_backend("radix", None, False) == "lexsort"
    with pytest.raises(ValueError):
        RX.resolve_sort_backend("quicksort", None, True)


def test_host_radix_argsort_matches_numpy():
    rng = np.random.default_rng(7)
    for t, live in [(1, 5), (500, 22), (4096, 60), (3000, 64)]:
        pool = rng.integers(0, 1 << min(live, 63), max(1, t // 2),
                            dtype=np.uint64)
        keys = pool[rng.integers(0, pool.shape[0], t)]
        np.testing.assert_array_equal(
            RX.radix_argsort_host(keys, live),
            np.argsort(keys, kind="stable"))


def _assert_results_identical(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name)


def _random_ctx(rng, sizes, t, values):
    tuples = np.stack([rng.integers(0, s, t, dtype=np.int32)
                       for s in sizes], 1)
    vals = (rng.uniform(0.001, 1000.0, t).astype(np.float32)
            if values else None)
    return tuples, vals


@pytest.mark.parametrize("sizes", [(7, 5), (9, 7, 5), (6, 5, 4, 3)])
def test_radix_prime_mining_leaf_identical(sizes):
    rng = np.random.default_rng(len(sizes))
    tuples, _ = _random_ctx(rng, sizes, 120, values=False)
    engines = {b: BatchMiner(sizes, sort_backend=b)
               for b in ("radix", "lax", "lexsort")}
    assert engines["radix"].packed_active
    assert not engines["lexsort"].packed_active
    res = {b: e(tuples) for b, e in engines.items()}
    _assert_results_identical(res["radix"], res["lax"])
    _assert_results_identical(res["radix"], res["lexsort"])


@pytest.mark.parametrize("delta", [0.0, 50.0])
def test_radix_noac_mining_leaf_identical(delta):
    sizes = (9, 7, 5)
    rng = np.random.default_rng(int(delta) + 1)
    tuples, vals = _random_ctx(rng, sizes, 100, values=True)
    res = {b: NOACMiner(sizes, delta=delta, sort_backend=b)(tuples, vals)
           for b in ("radix", "lax", "lexsort")}
    _assert_results_identical(res["radix"], res["lax"])
    _assert_results_identical(res["radix"], res["lexsort"])


def test_radix_over_64_bit_key_falls_back_to_lexsort():
    sizes = (1 << 17,) * 4        # 68-bit key: no packed path
    rng = np.random.default_rng(0)
    tuples = np.stack([rng.integers(0, s, 64, dtype=np.int32)
                       for s in sizes], 1)
    auto = BatchMiner(sizes, sort_backend="radix")
    assert auto.resolved_sort_backend == "lexsort"
    _assert_results_identical(auto(tuples),
                              BatchMiner(sizes, packed=False)(tuples))


def test_streaming_host_radix_snapshot_identical():
    """The host-side LSD chunk sorts + merged permutations (radix
    backend) reproduce the device sort exactly: incremental snapshots
    equal a full re-mine leaf-for-leaf, and the lax-backed stream
    agrees bit-for-bit."""
    from repro.core import StreamingMiner
    sizes = (9, 7, 5)
    rng = np.random.default_rng(3)
    tuples, _ = _random_ctx(rng, sizes, 96, values=False)
    res = {}
    for b in ("radix", "lax"):
        sm = StreamingMiner(sizes, sort_backend=b)
        for lo in range(0, 96, 32):
            sm.add(tuples[lo:lo + 32])
        res[b] = sm.snapshot()
        _assert_results_identical(res[b], sm.snapshot(full_remine=True))
    _assert_results_identical(res["radix"], res["lax"])


# ---------------------------------------------------------------------------
# Value-lane cardinality pruning (rank-coded value lane)
# ---------------------------------------------------------------------------

def test_value_lane_pruning_plan_layout():
    sizes = (6000, 3000, 8)            # 13 + 12 + 3 = 28 structural bits
    full = K.plan_context_keys(sizes, with_values=True)[0]
    assert full.value_bits == 32 and full.total_bits == 60
    pruned = K.plan_context_keys(sizes, with_values=True, value_slots=5)[0]
    assert pruned.value_bits == 3      # 5-star movielens domain
    assert pruned.total_bits == 31 and pruned.words == 1
    assert pruned.seg_shift == pruned.e_bits + 3
    # pruning halves the radix pass schedule at movielens scale
    assert RX.plan_radix(pruned.total_bits, 64_055).passes == 2
    assert RX.plan_radix(full.total_bits, 64_055).passes == 4


@pytest.mark.parametrize("n_distinct", [1, 2, 5, 40, 1000])
def test_pruned_lane_pack_parity_and_order(n_distinct):
    """Host and device packers agree bit-for-bit on the rank lane, and
    the rank-coded key sorts in exactly the float-lane order (rank
    coding is order-isomorphic), stability included."""
    sizes = (9, 7, 5)
    rng = np.random.default_rng(n_distinct)
    tuples, _ = _random_ctx(rng, sizes, 300, values=False)
    domain = np.unique(rng.uniform(-50, 50, n_distinct).astype(np.float32))
    vals = domain[rng.integers(0, domain.shape[0], 300)]
    for k in range(len(sizes)):
        pruned = K.plan_mode_key(sizes, k, True, domain.shape[0])
        full = K.plan_mode_key(sizes, k, True)
        host = pruned.pack_host(tuples, vals, domain=domain)
        dev = pruned.pack_device(jnp.asarray(tuples), jnp.asarray(vals),
                                 domain=jnp.asarray(domain))
        packed = np.asarray(dev[-1], np.uint64)
        if pruned.words == 2:
            packed |= np.asarray(dev[0], np.uint64) << np.uint64(32)
        np.testing.assert_array_equal(host, packed)
        np.testing.assert_array_equal(
            np.argsort(host, kind="stable"),
            np.argsort(full.pack_host(tuples, vals), kind="stable"))
        # the lane round-trips through the domain gather
        vals_back = pruned.extract_values(dev, domain=jnp.asarray(domain))
        np.testing.assert_array_equal(np.asarray(vals_back), vals)


def test_pruning_rescues_float_lane_overflow():
    """A key that exceeds 64 bits ONLY because of the 32-bit float lane
    packs (and radix-sorts) once the lane is rank-coded: 41 structural
    bits + 32 > 64 un-pruned, but + 3 rank bits = 44 fits.  The pruned
    path must engage (domain not gated off by the un-pruned ``fits``)
    and stay leaf-identical to the lexsort fallback."""
    sizes = (1 << 14, 1 << 14, 1 << 13)          # 14 + 14 + 13 = 41 bits
    assert not K.plan_context_keys(sizes, with_values=True)[0].fits
    assert K.plan_context_keys(sizes, with_values=True,
                               value_slots=5)[0].fits
    rng = np.random.default_rng(9)
    tuples = np.stack([rng.integers(0, s, 80, dtype=np.int32)
                       for s in sizes], 1)
    vals = rng.integers(0, 5, 80).astype(np.float32)
    miner = NOACMiner(sizes, delta=1.0)
    assert miner.value_domain(vals) is not None   # pruning engages
    res = miner(tuples, vals)
    base = NOACMiner(sizes, delta=1.0, prune_values=False)(tuples, vals)
    _assert_results_identical(res, base)          # un-pruned = lexsort path


def test_negative_delta_rejected():
    """δ < 0 makes the window [v-δ, v+δ] empty and would underflow the
    rank-coded lane's searchsorted bounds — rejected at every entry."""
    from repro.core import pipeline as P
    with pytest.raises(ValueError, match="delta"):
        NOACMiner((4, 4, 4), delta=-0.5)
    with pytest.raises(ValueError, match="delta"):
        P.mine_tuples(jnp.zeros((4, 3), jnp.int32),
                      [jnp.zeros((4,), jnp.uint32)] * 3,
                      [jnp.zeros((4,), jnp.uint32)] * 3,
                      values=jnp.zeros((4,), jnp.float32), delta=-1.0)


@pytest.mark.parametrize("delta", [0.0, 7.5, 200.0])
def test_pruned_lane_mining_identical_to_float_lane(delta):
    """NOAC with the pruned (rank) lane ≡ the 32-bit float lane ≡ the
    column lexsort, leaf-for-leaf — δ-windows included (the rank-coded
    query bounds must match the sort-bit queries exactly)."""
    sizes = (9, 7, 5)
    rng = np.random.default_rng(int(delta) + 11)
    tuples, _ = _random_ctx(rng, sizes, 150, values=False)
    # a small domain with exact float values (δ arithmetic lands both
    # on and between domain points)
    vals = rng.integers(0, 8, 150).astype(np.float32) * np.float32(12.5)
    res = {}
    for name, kw in {"pruned": dict(sort_backend="radix"),
                     "float": dict(sort_backend="radix",
                                   prune_values=False),
                     "lax": dict(sort_backend="lax"),
                     "lexsort": dict(sort_backend="lexsort")}.items():
        res[name] = NOACMiner(sizes, delta=delta, **kw)(tuples, vals)
    _assert_results_identical(res["pruned"], res["float"])
    _assert_results_identical(res["pruned"], res["lax"])
    _assert_results_identical(res["pruned"], res["lexsort"])


# ---------------------------------------------------------------------------
# Hypothesis widening (CI only; mirrors tests/test_keys_property.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover - CI installs it
    st = None

if st is not None:
    @st.composite
    def word_arrays(draw):
        t = draw(st.integers(1, 200))
        live = draw(st.integers(1, 64))
        seed = draw(st.integers(0, 2**16))
        dup = draw(st.floats(0.0, 0.9))
        rng = np.random.default_rng(seed)
        return _random_words(rng, t, live, dup), live

    @settings(max_examples=40, deadline=None)
    @given(word_arrays(), st.booleans())
    def test_hypothesis_radix_perm_stable(words_live, use_pallas):
        (words, live) = words_live
        ref = _ref_sort(words, len(words))
        perm = RX.radix_sort_perm(words, live, use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(perm), np.asarray(ref[-1]))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 6), st.integers(2, 6), st.integers(2, 6),
           st.integers(1, 40), st.integers(0, 2**16),
           st.one_of(st.none(), st.floats(0.0, 500.0)))
    def test_hypothesis_radix_mining_leaf_identical(a, b, c, t, seed, delta):
        sizes = (a, b, c)
        rng = np.random.default_rng(seed)
        tuples, vals = _random_ctx(rng, sizes, t, values=delta is not None)
        if delta is None:
            res = {k: BatchMiner(sizes, sort_backend=k)(tuples)
                   for k in ("radix", "lax", "lexsort")}
        else:
            res = {k: NOACMiner(sizes, delta=delta,
                                sort_backend=k)(tuples, vals)
                   for k in ("radix", "lax", "lexsort")}
        _assert_results_identical(res["radix"], res["lax"])
        _assert_results_identical(res["radix"], res["lexsort"])
