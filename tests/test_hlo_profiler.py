"""Scan-aware HLO profiler: unit tests on synthetic HLO text + a live
check that while-body FLOPs are multiplied by the trip count."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import parse_module, profile_module
from repro.analysis.roofline import model_flops


_SYNTHETIC = """\
HloModule test

%fused_dus (p0: f32[8,16], p1: f32[1,16], p2: s32[]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[1,16]{1,0} parameter(1)
  %p2 = s32[] parameter(2)
  ROOT %dus = f32[8,16]{1,0} dynamic-update-slice(%p0, %p1, %p2, %p2)
}

%body (arg: (s32[], f32[16,16], f32[8,16])) -> (s32[], f32[16,16], f32[8,16]) {
  %arg = (s32[], f32[16,16]{1,0}, f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[16,16]{1,0} get-tuple-element(%arg), index=1
  %acc = f32[8,16]{1,0} get-tuple-element(%arg), index=2
  %dot = f32[16,16]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %row = f32[1,16]{1,0} bitcast(%dot)
  %upd = f32[8,16]{1,0} fusion(%acc, %row, %i), kind=kLoop, calls=%fused_dus
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[16,16]{1,0}, f32[8,16]{1,0}) tuple(%ip, %dot, %upd)
}

%cond (arg: (s32[], f32[16,16], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[16,16]{1,0}, f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[16,16], b: f32[8,16]) -> (s32[], f32[16,16], f32[8,16]) {
  %a = f32[16,16]{1,0} parameter(0)
  %b = f32[8,16]{1,0} parameter(1)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,16]{1,0}, f32[8,16]{1,0}) tuple(%zero, %a, %b)
  ROOT %w = (s32[], f32[16,16]{1,0}, f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
}
"""


def test_parse_module_structure():
    comps, entry = parse_module(_SYNTHETIC)
    assert entry == "main"
    assert set(comps) == {"fused_dus", "body", "cond", "main"}
    assert comps["body"].instrs["%dot"].opcode == "dot"


def test_trip_count_scaling_and_dus_accounting():
    prof = profile_module(_SYNTHETIC, 1)
    # dot: 2*16*16*16 flops, executed 8 times
    assert prof.mxu_flops == 8 * 2 * 16 * 16 * 16
    assert prof.trip_counts.get("body") == 8
    # DUS fusion writes one 64-byte row per iteration, not the 512B buffer
    assert prof.traffic_bytes < 8 * (3 * 16 * 16 * 4) * 2


def test_live_scan_flops_counted_per_trip():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    n_layers, d = 12, 32
    comp = jax.jit(jax.grad(f)).lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((n_layers, d, d), jnp.float32)).compile()
    prof = profile_module(comp.as_text(), 1)
    # fwd dot + dx dot per layer (grad wrt x only)
    want = 2 * n_layers * 2 * d ** 3
    assert abs(prof.mxu_flops - want) / want < 0.05
    from repro.analysis.roofline import cost_analysis_dict
    raw = cost_analysis_dict(comp)["flops"]
    assert prof.mxu_flops > 4 * raw   # XLA counted the body once


def test_model_flops_shapes():
    from repro.configs import SHAPES, get_config
    cfg = get_config("qwen3-0.6b")
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.n_active_params()
    assert t == 6 * n * 4096 * 256
    assert p == 2 * n * 32768 * 32
    assert d == 2 * n * 128
