"""Inject rendered roofline tables into EXPERIMENTS.md placeholders.

    PYTHONPATH=src python scripts/render_experiments.py
"""
import sys

sys.path.insert(0, "src")

from repro.analysis.report import dryrun_summary, load, roofline_table  # noqa


def main():
    rows = load("results/dryrun_final.jsonl")
    summary = dryrun_summary(rows)
    tables = []
    for mesh in ("1pod", "2pod"):
        tables.append(f"### {mesh} "
                      f"({'256' if mesh == '1pod' else '512'} chips)\n")
        tables.append(roofline_table(rows, mesh))
        tables.append("")
    final_tables = "\n".join(tables)

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- ROOFLINE_SUMMARY -->", summary)
    text = text.replace("<!-- FINAL_TABLES -->", final_tables)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("injected summary + tables")


if __name__ == "__main__":
    main()
