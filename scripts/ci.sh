#!/usr/bin/env bash
# CI entry point: install dev deps, run the tier-1 suite (ROADMAP.md),
# then the bench-smoke step: a tiny-scale benchmark run — sort-path
# comparison, run-store section (out-of-core + incremental-distributed
# snapshots) and the fixed calibration probe — whose
# results/BENCH_smoke.json must pass the schema gate
# (benchmarks/validate.py).
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

echo "== bench smoke (tiny scale) + BENCH_mining.json schema gate =="
# smoke output goes to an untracked file so the committed full-scale
# perf trajectory (results/BENCH_mining.json) is never clobbered
python -m benchmarks.run --scale 0.004 --repeat 1 --only packed \
    --out BENCH_smoke.json
python -m benchmarks.validate results/BENCH_smoke.json
