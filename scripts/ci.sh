#!/usr/bin/env bash
# CI entry point: install dev deps, run the tier-1 suite (ROADMAP.md),
# then the smoke steps:
#   * bench smoke — tiny-scale benchmark run (sort-path comparison,
#     run-store section, calibration probe, serving load test) whose
#     results/BENCH_smoke.json must pass the schema gate
#     (benchmarks/validate.py, incl. the serving section);
#   * serve smoke — boot launch/cluster_serve.py on an ephemeral port
#     and drive it through scalar/batch/top-k/signature queries, an
#     upsert, a version-advancing refresh and a clean shutdown;
#   * chaos smoke — benchmarks/chaos.py kill-and-restart cycle through
#     a supervised 2x2 plane: zero gateway 5xx, bounded recovery,
#     bit-identical post-recovery answers (serving_faults schema gate);
#   * integrity smoke — benchmarks/chaos.py corruption drill: inject
#     WAL bit rot, checkpoint truncation and shm word flips; every
#     corruption must be detected (zero silently-wrong answers),
#     recovery bit-identical, clean-path checksum cost <= 5% of a
#     snapshot swap (serving_integrity schema gate);
#   * window smoke — mine a small context through the windowed device
#     pipeline (DESIGN.md §3c) with a deliberately tiny budget
#     (>= 8 windows) and assert bit-parity against the monolithic path;
#   * obs smoke — boot a 2x1 plane with --metrics, scrape /metrics and
#     assert one query's trace id reconstructs the router span tree
#     (/debug/trace) and lands in the slow log (/debug/slow);
#   * trend smoke — render the calibration-normalised cross-PR trend
#     report from the git history of results/BENCH_mining.json.
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

echo "== bench smoke (tiny scale) + BENCH_mining.json schema gate =="
# smoke output goes to an untracked file so the committed full-scale
# perf trajectory (results/BENCH_mining.json) is never clobbered
python -m benchmarks.run --scale 0.004 --repeat 1 --only packed,serving \
    --out BENCH_smoke.json
python -m benchmarks.validate results/BENCH_smoke.json

echo "== serve smoke (cluster_serve endpoint round-trip) =="
PORT_FILE="$(mktemp)"
rm -f "$PORT_FILE"
python -m repro.launch.cluster_serve --dataset random --n-tuples 1024 \
    --port 0 --port-file "$PORT_FILE" &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT
python -m repro.launch.cluster_serve --smoke-client \
    --port-file "$PORT_FILE" --timeout 120
wait "$SERVE_PID"   # /shutdown from the smoke client stops the server
trap - EXIT
rm -f "$PORT_FILE"

echo "== serve smoke (router: 2 shards x 2 replica readers) =="
# same smoke sequence through the radix-range router topology: the
# client detects role=router and additionally verifies cross-shard
# read-your-writes via per-shard write tokens (at_least_version)
PORT_FILE="$(mktemp)"
rm -f "$PORT_FILE"
python -m repro.launch.cluster_serve --dataset random --n-tuples 1024 \
    --shards 2 --replicas 2 --port 0 --port-file "$PORT_FILE" &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT
python -m repro.launch.cluster_serve --smoke-client \
    --port-file "$PORT_FILE" --timeout 240
wait "$SERVE_PID"
trap - EXIT
rm -f "$PORT_FILE"

echo "== chaos smoke (supervised kill-and-restart, zero gateway 5xx) =="
# 2x2 supervised plane; a seeded FaultPlan kills one shard writer
# mid-trickle (checkpoint+WAL recovery) and one replica (shm
# re-attach).  Gates: no query surfaces a gateway 5xx, full coverage
# restored inside the bound, the recovered writer bit-identical to an
# uninterrupted control — asserted in-run, then schema-gated.
# smoke output goes to an untracked file (same convention as the
# bench smoke): the committed full-scale results/chaos.json survives
python - <<'EOF'
from benchmarks.chaos import run
run(scale=0.004, out_name="chaos_smoke.json")
EOF
python -m benchmarks.validate results/chaos_smoke.json

echo "== integrity smoke (injected corruption detected + recovered) =="
# corruption drill over every durable surface: flip a WAL byte at a
# committed record (interior poison -> quarantine + forced
# checkpoint), truncate the newest checkpoint generation (fall back to
# the previous one + WAL replay), flip a word in a published shm
# segment (replica refuses the attach, keeps its held snapshot).
# Gates asserted in-run and then schema-checked: detected == injected,
# zero silently-wrong answers, bit-identical recovery, and the
# clean-path checksum pass <= 5% of the snapshot-swap it defends
python - <<'EOF'
from benchmarks.chaos import run_integrity
run_integrity(scale=0.004, out_name="integrity_smoke.json")
EOF
python -m benchmarks.validate results/integrity_smoke.json

echo "== window smoke (>= 8 HBM windows, bit-parity vs monolithic) =="
# a tiny window budget forces the seam-carry machinery through many
# windows on a real (valued, NOAC) context; every result leaf —
# permutations and signatures included — must equal the monolithic run
python - <<'EOF'
import dataclasses
import numpy as np
from repro.core import mine
from repro.data import synthetic
ctx = synthetic.movielens_like(n_tuples=4000, seed=0).deduplicated()
budget = -(-ctx.tuples.shape[0] // 8)
for variant, kw in (("prime", {}), ("noac", {"delta": 1.0})):
    mono = mine(ctx, backend="batch", variant=variant, **kw)
    win = mine(ctx, backend="batch", variant=variant,
               window_budget=budget, **kw)
    n_windows = -(-ctx.tuples.shape[0] // budget)
    assert n_windows >= 8, n_windows
    for f in dataclasses.fields(mono.result):
        np.testing.assert_array_equal(
            np.asarray(getattr(mono.result, f.name)),
            np.asarray(getattr(win.result, f.name)),
            err_msg=f"{variant}:{f.name}")
    print(f"[window-smoke] {variant}: {n_windows} windows, "
          f"{win.n_clusters} clusters, bit-identical")
EOF

echo "== obs smoke (metrics scrape + cross-process trace round-trip) =="
# 2x1 plane booted with --metrics: one fanned-out query's trace id
# must reconstruct a span tree on the router (/debug/trace — root +
# one router.shard span per shard), appear in the slow log with its
# queue-wait/handler split (--slow-query-ms 0 records everything),
# and the Prometheus exposition (/metrics) must carry both the
# registry instruments and the folded resilience collectors
PORT_FILE="$(mktemp)"
rm -f "$PORT_FILE"
python -m repro.launch.cluster_serve --dataset random --n-tuples 1024 \
    --shards 2 --replicas 1 --metrics --slow-query-ms 0 \
    --port 0 --port-file "$PORT_FILE" &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT
PORT_FILE="$PORT_FILE" python - <<'EOF'
import json, os, re, time, urllib.request

def get(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.read().decode()

def post(url, doc):
    req = urllib.request.Request(
        url, data=json.dumps(doc).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=30) as r:
        return json.loads(r.read().decode())

path = os.environ["PORT_FILE"]
deadline = time.monotonic() + 120
while not (os.path.exists(path) and open(path).read().strip()):
    assert time.monotonic() < deadline, "router port file"
    time.sleep(0.05)
base = f"http://127.0.0.1:{int(open(path).read())}"
while True:
    try:
        get(f"{base}/metrics")
        break
    except OSError:
        assert time.monotonic() < deadline, "router /metrics"
        time.sleep(0.05)

out = post(f"{base}/query", {"k": 5})
tid = out["trace_id"]
assert re.fullmatch(r"[0-9a-f]{16}", tid), tid
# router records span -> metrics -> slow entry after replying: the
# slow entry arriving means the whole trace is in the ring
while not any(e.get("trace_id") == tid
              for e in json.loads(get(f"{base}/debug/slow"))["slowest"]):
    assert time.monotonic() < deadline, "slow-log entry"
    time.sleep(0.05)
spans = json.loads(get(f"{base}/debug/trace?trace_id={tid}"))["spans"]
names = [s["name"] for s in spans]
(root,) = [s for s in spans if s["name"] == "router/query"]
assert root["parent_id"] is None
shards = {s["attrs"]["shard"] for s in spans
          if s["name"] == "router.shard"}
assert shards == {0, 1}, shards
text = get(f"{base}/metrics")
assert 'repro_router_request_ms_count{endpoint="/query"}' in text
assert "repro_router_breaker_open" in text
ent = next(e for e in json.loads(get(f"{base}/debug/slow"))["slowest"]
           if e["trace_id"] == tid)
assert ent["handler_ms"] is not None and ent["wait_ms"] is not None
post(f"{base}/shutdown", {})
print(f"[obs-smoke] trace {tid}: {len(spans)} router spans "
      f"({sorted(set(names))}), slow log + exposition OK")
EOF
wait "$SERVE_PID"
trap - EXIT
rm -f "$PORT_FILE"

echo "== trend smoke (calibration-normalised cross-PR report) =="
python scripts/render_trend.py --limit 8
