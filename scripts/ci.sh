#!/usr/bin/env bash
# CI entry point: install dev deps and run the tier-1 suite (ROADMAP.md).
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
