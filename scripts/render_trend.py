"""Cross-PR benchmark trend report (ROADMAP "benchmark hygiene, part 2").

Every PR commits a regenerated ``results/BENCH_mining.json`` carrying a
fixed machine-speed probe (``calibration``: one radix sort of the SAME
100k uint32 words each time).  This script walks the file's git history,
pulls each committed version, and renders one trend table in which
wall-times are *normalised by that probe* — ``ms / calibration_ms`` is a
machine-independent "calibration unit", so a PR run on a slow or noisy
machine doesn't masquerade as a regression (speedup *ratios* within one
run were already machine-independent and are reported as-is).

Stdlib only (git + json): ``python scripts/render_trend.py
[--limit N] [--out results/TREND.md]``.  Outside a git checkout it
degrades to a single-row report of the working-tree file.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git(*args: str) -> str:
    return subprocess.check_output(("git", "-C", REPO) + args,
                                   text=True, stderr=subprocess.DEVNULL)


def history(path: str, limit: int) -> list:
    """[(label, subject, doc)] newest-first: the working tree copy (when
    it differs from HEAD) plus each committed version of ``path``."""
    out = []
    try:
        with open(os.path.join(REPO, path)) as f:
            wt = json.load(f)
    except (OSError, json.JSONDecodeError):
        wt = None
    revs = []
    try:
        log = _git("log", "--format=%h\x1f%s", "--", path)
        revs = [ln.split("\x1f", 1) for ln in log.splitlines() if ln]
    except (subprocess.SubprocessError, OSError):
        pass
    docs = []
    for sha, subject in revs[:limit]:
        try:
            doc = json.loads(_git("show", f"{sha}:{path}"))
        except (subprocess.SubprocessError, OSError,
                json.JSONDecodeError):
            continue
        if isinstance(doc, dict):    # pre-schema commits: skip quietly
            docs.append((sha, subject, doc))
    if isinstance(wt, dict) and (not docs or wt != docs[0][2]):
        out.append(("worktree", "(uncommitted)", wt))
    return out + docs


def _pick_e2e(doc: dict, variant: str):
    """Representative end-to-end ms: the packed-radix (else packed-lax)
    batch row of the sort-path comparison — present since the probes
    were introduced; None for older documents."""
    rows = [r for r in doc.get("rows", []) if isinstance(r, dict)
            and r.get("backend") == "batch" and r.get("variant") == variant]
    for path in ("packed-radix", "packed-lax"):
        for r in rows:
            if r.get("sort_path") == path and r.get("ms") is not None:
                return float(r["ms"])
    return None


def _fmt(v, spec="{:.2f}", dash="-"):
    return dash if v is None else spec.format(v)


def trend_rows(hist: list) -> list:
    """One report row per document; every section is optional — a
    historical commit predating a section (e.g. pre-PR-5 files have no
    ``serving``, pre-PR-6 no ``serving_scale``) renders dashes for its
    columns instead of aborting the whole report."""
    rows = []
    for label, subject, doc in hist:
        row = {"rev": label, "subject": subject, "cal_ms": None}
        rows.append(row)
        try:
            cal = (doc.get("calibration") or {}).get("ms")
            row["cal_ms"] = cal
            for variant in ("prime", "noac"):
                ms = _pick_e2e(doc, variant)
                row[f"{variant}_ms"] = ms
                row[f"{variant}_x_cal"] = (None if not cal or ms is None
                                           else ms / cal)
                sp = (doc.get("radix_speedup") or {}).get(variant) or {}
                row[f"{variant}_radix_sp"] = sp.get("end_to_end")
            runs = doc.get("runs_speedup") or {}
            row["inc_snapshot_sp"] = (runs.get("prime") or {}).get(
                "incremental_snapshot")
            srv = doc.get("serving") or {}
            row["serve_p50_ms"] = srv.get("p50_ms")
            row["serve_p50_x_cal"] = (None if not cal
                                      or not srv.get("p50_ms")
                                      else srv["p50_ms"] / cal)
            row["serve_batch_sp"] = srv.get("batch_speedup_at_64")
            scale = doc.get("serving_scale") or {}
            row["delta_sp"] = (scale.get("delta") or {}).get("speedup")
            row["qps_ratio"] = (scale.get("replica_scaleout") or {}).get(
                "qps_ratio")
            # windowed device pipeline (DESIGN.md §3c): equal-T
            # throughput ratio + peak-allocation ratio; docs predating
            # the section render dashes
            win = (doc.get("windowed") or {}).get("prime") or {}
            row["win_tp"] = win.get("throughput_ratio")
            row["win_peak"] = win.get("peak_ratio")
            # observability plane (DESIGN.md §11): instrumentation
            # overhead on query p50 (within-run %, machine-independent)
            # and the registry histogram's own p99 estimate in
            # calibration units; docs predating the section get dashes
            obs = doc.get("serving_obs") or {}
            row["obs_ovh"] = obs.get("query_overhead_pct")
            p99h = obs.get("query_p99_hist_ms")
            row["obs_p99_x_cal"] = (None if not cal or not p99h
                                    else p99h / cal)
        except (TypeError, ValueError, AttributeError):
            # malformed historical document: keep the rev visible with
            # whatever was extracted before the fault
            continue
    return rows


HEADERS = [("rev", "rev"), ("cal_ms", "cal ms"),
           ("prime_ms", "prime ms"), ("prime_x_cal", "×cal"),
           ("noac_ms", "noac ms"), ("noac_x_cal", "×cal"),
           ("prime_radix_sp", "radix sp"),
           ("inc_snapshot_sp", "inc-snap sp"),
           ("serve_p50_x_cal", "serve p50 ×cal"),
           ("serve_batch_sp", "batch sp"),
           ("delta_sp", "delta sp"), ("qps_ratio", "qps ratio"),
           ("win_tp", "win tp"), ("win_peak", "win peak"),
           ("obs_ovh", "obs ovh%"), ("obs_p99_x_cal", "obs p99 ×cal")]


def render(rows: list) -> str:
    lines = ["# Benchmark trend (normalised by the calibration probe)",
             "",
             "`×cal` = wall ms ÷ calibration-probe ms "
             "(`radix_sort_perm_100k_u32`): machine-independent "
             "calibration units; speedup columns are within-run ratios. "
             "Newest first.", ""]
    head = [h for _, h in HEADERS]
    lines.append("| " + " | ".join(head) + " | subject |")
    lines.append("|" + "---|" * (len(head) + 1))
    for r in rows:
        cells = [_fmt(r.get(key)) if key != "rev" else r["rev"]
                 for key, _ in HEADERS]
        subject = r["subject"]
        subject = subject if len(subject) <= 48 else subject[:45] + "..."
        lines.append("| " + " | ".join(cells) + f" | {subject} |")
    return "\n".join(lines) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="results/BENCH_mining.json")
    ap.add_argument("--limit", type=int, default=20,
                    help="max commits to walk back")
    ap.add_argument("--out", default="",
                    help="also write the markdown report here")
    args = ap.parse_args(argv)
    hist = history(args.path, args.limit)
    if not hist:
        # empty history is a state, not a failure: fresh checkouts and
        # shallow clones run the trend step before any benchmark commit
        print(f"[trend] no readable versions of {args.path} — "
              "nothing to report yet")
        return 0
    text = render(trend_rows(hist))
    print(text)
    if args.out:
        out = os.path.join(REPO, args.out)
        with open(out, "w") as f:
            f.write(text)
        print(f"[trend] wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
